//! `.vsz` container formats.
//!
//! # v1 — monolithic container (whole-field, in-memory)
//!
//! Layout (all little-endian):
//! ```text
//! magic "VSZ1" | u16 version=1 | u8 ndim | u8 codes_kind | u64 dims[3]
//! f64 eb | u16 radius | u32 block_size
//! u8 pad_value | u8 pad_granularity
//! u8 n_sections, then per section:
//!   u8 tag | uvarint raw_len | uvarint enc_len | u32 crc32(payload) | bytes
//! ```
//!
//! # v2 — chunked streaming container (out-of-core fields)
//!
//! The field is framed as a sequence of independently-decodable **chunks**:
//! contiguous slabs along the leading dimension, each a whole number of
//! block rows so blocks never straddle a chunk boundary. Every chunk
//! carries its own CODES / OUTLIER_POS / OUTLIER_VAL / PAD_SCALARS sections
//! with the same per-section CRC framing as v1, so a single flipped byte is
//! detected at the chunk that owns it and decode of the other chunks can
//! proceed (or the whole read can be rejected, as `decompress` does).
//!
//! ```text
//! magic "VSZ2" | u16 version=2 | u8 ndim | u8 codes_kind | u64 dims[3]
//! f64 eb | u16 radius | u32 block_size
//! u8 pad_value | u8 pad_granularity
//! u64 chunk_span                  -- leading-dim extent of a full chunk
//! then, per chunk (in leading-dim order):
//!   u8 0xC7 | uvarint chunk_index | uvarint lead_extent | u8 n_sections
//!   per section: u8 tag | uvarint raw_len | uvarint enc_len
//!                | u32 crc32(payload) | bytes
//! trailer:
//!   u8 0xE7 | uvarint n_chunks | u32 crc32(n_chunks as u64 LE)
//! ```
//!
//! Chunk framing is what enables the streaming engine (`stream`): the
//! writer emits the fixed-size header, then one frame per slab as data
//! arrives (bounded memory), and the reader decodes frames one at a time —
//! or hands batches of frames to the thread pool for chunk-parallel decode
//! (cuSZ-style coarse-grained parallelism).
//!
//! # v3 — indexed streaming container (random access / partial decode)
//!
//! v3 keeps the v2 chunk framing and adds two things: **per-chunk encode
//! configuration** and a **seekable index footer**, the combination that
//! makes a chunk decodable without touching any other byte of the file
//! (the SZx/cuSZ partial-retrieval idea).
//!
//! ```text
//! magic "VSZ3" | u16 version=3 | ...same header fields as v2... | u64 chunk_span
//! then, per chunk (in leading-dim order):
//!   u8 0xC7 | uvarint chunk_index | uvarint lead_extent
//!   uvarint block_size | u8 lane_width      -- per-chunk config (v3 only)
//!   u8 n_sections | sections as in v2
//! trailer:
//!   u8 0xE7 | uvarint n_chunks | u32 crc32(n_chunks as u64 LE)
//! index footer (last bytes of the file):
//!   u8 0xD3 | uvarint n_chunks
//!   n_chunks x (uvarint offset | uvarint frame_len | uvarint lead_extent
//!               | uvarint block_size | u8 lane_width)
//!   u32 crc32(0xD3 .. last entry)
//!   u32 footer_len                 -- bytes from 0xD3 through the crc
//! ```
//!
//! `offset` is the byte position of the chunk's `0xC7` marker from the
//! start of the container; frames are contiguous from the header, which
//! the readers verify. The footer is **length-suffixed** so a reader can
//! `open()` a file, read the trailing 4 bytes, seek back `footer_len`
//! bytes, CRC-check the index and then fetch exactly `frame_len` bytes of
//! any chunk. The per-chunk `block_size` exists because the streaming
//! compressor may re-run the autotune heuristic per chunk
//! ([`crate::stream::StreamOptions`]); `lane_width` records the SIMD lane
//! count the encoder picked, with bit 7 ([`WIDTH_SIMD_FLAG`]) marking the
//! explicit-intrinsics `simd` backend (informational — it does not affect
//! decode).
//!
//! **Version-dispatch compatibility rule:** `compressor::decompress`
//! dispatches on the leading magic — `VSZ1` monolithic, `VSZ2` chunked,
//! `VSZ3` chunked + indexed — and all three decode through the same
//! section cores, so every container this crate has ever written keeps
//! decoding bit-exactly. v2 readers of *this* crate reject v3 input by
//! magic (never misparse it), and the v3 reader accepts v2 containers
//! (the index-footer APIs then report "no index" instead of seeking).
//!
//! Section payloads are already entropy-coded by their producers (Huffman
//! for codes, lossless for outlier streams); the container adds integrity
//! and framing only.
//!
//! # v3 parity layer (optional) — parity frames + footer v2
//!
//! A v3 container may carry an **XOR parity layer** (`--parity G`): the
//! chunk frames are grouped in written order into groups of `G` (the last
//! group may be shorter) and one parity frame per group is emitted after
//! the last data frame, before the end trailer:
//!
//! ```text
//! u8 0xB7 | uvarint group_index | uvarint n_members
//! uvarint payload_len | u32 crc32(payload) | payload
//! ```
//!
//! **XOR padding rule:** `payload_len` is the byte length of the longest
//! member frame in the group, and the payload is the byte-wise XOR of the
//! member frames with each member **zero-padded at the tail** to
//! `payload_len`. Any single member frame can therefore be rebuilt as the
//! XOR of the parity payload with the other members (each zero-padded the
//! same way), truncated to that member's indexed `frame_len`; the rebuilt
//! frame's own section CRCs are the acceptance test.
//!
//! Parity geometry lives in a **footer v2** that replaces the plain index
//! footer when (and only when) parity is enabled — parity-less v3 output
//! is byte-identical to pre-parity builds:
//!
//! ```text
//! u8 0xD4 | uvarint group_size
//! uvarint n_chunks | n_chunks x (entry as in footer v1)
//! uvarint n_parity | n_parity x (uvarint offset | uvarint frame_len)
//! u32 crc32(0xD4 .. last parity entry)
//! u32 footer_len
//! ```
//!
//! `n_parity` must equal `ceil(n_chunks / group_size)`. Readers dispatch
//! on the footer's first byte (`0xD3` v1, `0xD4` v2); pre-parity readers
//! reject the `0xD4` tag rather than misparse it, and current readers
//! skip parity frames they don't need. A group with **two or more**
//! lost/corrupt frames is beyond the parity's reach and stays an error.
//!
//! # CODES payload framing (`HUF2` / `HUF3`)
//!
//! Since the parallel entropy stage, the CODES section of **both**
//! container versions carries a chunked Huffman payload. The first
//! framing revision ([`crate::huffman::compress_u16_chunked`]):
//!
//! ```text
//! magic 0xF5 'H' 'F' '2'
//! code-table header (varint alphabet, varint n_pairs, (delta-sym, len)*)
//! uvarint chunk_syms               -- symbols per full chunk (2^16)
//! uvarint n_chunks
//! n_chunks x (uvarint sym_count | uvarint bit_len)   -- chunk offset table
//! concatenated chunk payloads, each byte-aligned (ceil(bit_len/8) bytes)
//! ```
//!
//! Chunks are fixed-size symbol ranges — geometry never depends on the
//! worker count, so the payload bytes are identical for every thread
//! count — and each chunk is an independently decodable bitstream, which
//! is what lets encode and decode fan out across the thread pool.
//!
//! The entropy engine v2 revision (`HUF3`,
//! [`crate::huffman::compress_u16_framed`]) is what new containers write.
//! It keeps the HUF2 chunk geometry and adds two per-chunk options, each
//! announced by a flag bit in the chunk's entry (unknown flag bits reject
//! the payload):
//!
//! ```text
//! magic 0xF7 'H' 'F' '3'
//! shared code-table header (as above)
//! uvarint chunk_syms | uvarint gap_interval (0 = none) | uvarint n_chunks
//! n_chunks x ( u8 flags                 -- bit0 local table, bit1 gap array
//!            | uvarint sym_count | uvarint bit_len
//!            | uvarint table_len  when bit0
//!            | uvarint gap_len    when bit1 )
//! per chunk, concatenated:
//!   [local code table: table_len bytes, same header format]
//!   [gap blob: u32-LE crc32 | uvarint n_points | ascending bit-offset
//!    delta uvarints]
//!   bitstream (ceil(bit_len/8) bytes)
//! ```
//!
//! * **Gap array** — gap point `k` is the absolute bit offset where chunk
//!   symbol `(k+1) * gap_interval` starts, so the decoder can split one
//!   chunk's bitstream into independently-decoded segments across the
//!   pool (a single-chunk container scales on threads). The blob is CRC32
//!   guarded and each segment must consume exactly its bit span, so a
//!   corrupt resync point errors instead of mis-decoding.
//! * **Per-chunk code table** — carried only when the chunk-local
//!   canonical table beats the shared one by at least
//!   [`crate::huffman::LOCAL_TABLE_MIN_GAIN`] bytes including its own
//!   header (size gate), which pays on non-stationary streams and costs
//!   stationary streams nothing.
//!
//! **Backward compatibility:** the decoder dispatches on the magic
//! (`HUF2` → chunked, `HUF3` → framed); a CODES payload that starts with
//! neither is parsed as the legacy pre-HUF2 unframed stream (one
//! code-table header, varint count, one monolithic bitstream), so every
//! container written before these framings existed still decodes
//! bit-exactly. Legacy payloads begin with the uvarint of the alphabet
//! size — always even (`2 * radius`, or 256 for lossless token streams)
//! — while both magics' first bytes are odd, so the dispatch is
//! unambiguous for every payload this crate has ever written. Large
//! lossless side-streams (outlier positions/values, pad scalars) adopt
//! the same HUF3 framing above a size threshold via their own container
//! tag (see [`crate::lossless`]).

use crate::bitio::{put_uvarint, Cursor};
use crate::blocks::Dims;
use crate::error::{Result, VszError};
use crate::padding::{PadGranularity, PadValue, PaddingPolicy};
use crate::quant::CodesKind;
use crate::util::crc32;

pub const MAGIC: &[u8; 4] = b"VSZ1";
pub const VERSION: u16 = 1;

pub const MAGIC2: &[u8; 4] = b"VSZ2";
pub const VERSION2: u16 = 2;

pub const MAGIC3: &[u8; 4] = b"VSZ3";
pub const VERSION3: u16 = 3;

/// Frame markers of the v2/v3 streaming containers.
pub const CHUNK_TAG: u8 = 0xC7;
pub const END_TAG: u8 = 0xE7;
/// First byte of the v3 index footer.
pub const INDEX_TAG: u8 = 0xD3;
/// First byte of the parity-extended index footer (footer v2), written
/// only when the container carries a parity layer.
pub const INDEX_TAG2: u8 = 0xD4;
/// First byte of a parity frame (one per parity group, after the data
/// frames).
pub const PARITY_TAG: u8 = 0xB7;

/// Serialized size of the v2/v3 stream header (fixed — no section count).
pub const STREAM_HEADER_LEN: usize = 4 + 2 + 1 + 1 + 24 + 8 + 2 + 4 + 1 + 1 + 8;

/// Block-size bounds every reader enforces — one source of truth for the
/// v3 chunk-meta parsers and `decode_body`'s header check, so a container
/// accepted by one decode path is accepted by all of them.
pub const MIN_BLOCK_SIZE: u64 = 2;
pub const MAX_BLOCK_SIZE: u64 = 1 << 20;

/// Validate a parsed block size against [`MIN_BLOCK_SIZE`]/
/// [`MAX_BLOCK_SIZE`].
pub fn check_block_size(bs: u64) -> Result<u32> {
    if !(MIN_BLOCK_SIZE..=MAX_BLOCK_SIZE).contains(&bs) {
        return Err(VszError::format(format!("bad block size {bs}")));
    }
    Ok(bs as u32)
}

/// Section tags.
pub mod tag {
    /// Huffman-coded quant codes (HUF3 framed; HUF2 chunked and legacy
    /// unframed payloads from older containers are still accepted — see
    /// the module doc).
    pub const CODES: u8 = 1;
    /// Outlier positions (delta varints, lossless-compressed).
    pub const OUTLIER_POS: u8 = 2;
    /// Outlier values (f32 LE bytes, lossless-compressed).
    pub const OUTLIER_VAL: u8 = 3;
    /// Padding scalars (f32 LE bytes, lossless-compressed).
    pub const PAD_SCALARS: u8 = 4;
}

/// Parsed container header.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Header {
    pub dims: Dims,
    pub codes_kind: CodesKind,
    pub eb: f64,
    pub radius: u16,
    pub block_size: u32,
    pub padding: PaddingPolicy,
}

/// v2/v3 stream header: the v1 header fields plus the chunking geometry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamHeader {
    pub header: Header,
    /// Leading-dimension extent of every full chunk (the last chunk may be
    /// shorter). Always a multiple of the *base* block size (per-chunk
    /// autotuning may encode an individual chunk with a different block
    /// size; the span stays fixed).
    pub chunk_span: u64,
    /// Container version: [`VERSION2`] (no footer) or [`VERSION3`]
    /// (per-chunk config + index footer).
    pub version: u16,
}

/// Per-chunk encode configuration carried by v3 chunk frames and the index
/// footer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkMeta {
    /// Block size this chunk was encoded with (drives decode geometry).
    pub block_size: u32,
    /// SIMD lane width the encoder used (informational; 0 = scalar/SZ-1.4
    /// backend). Bit 7 ([`WIDTH_SIMD_FLAG`]) marks the explicit-intrinsics
    /// `simd` backend; the low 7 bits are the lane width. Decoders ignore
    /// the byte entirely (codes are backend-independent), so the flag is
    /// forward- and backward-compatible.
    pub width: u8,
}

/// High bit of [`ChunkMeta::width`]: set when the chunk was encoded with
/// the explicit-intrinsics `simd` backend rather than the autovectorized
/// `vec` backend.
pub const WIDTH_SIMD_FLAG: u8 = 0x80;

impl ChunkMeta {
    /// Lane width without the backend flag.
    pub fn lane_width(&self) -> u8 {
        self.width & !WIDTH_SIMD_FLAG
    }

    /// Was this chunk encoded by the explicit-intrinsics backend?
    pub fn is_simd(&self) -> bool {
        self.width & WIDTH_SIMD_FLAG != 0
    }

    /// Display label for `vsz stream inspect` (`vec8` / `simd16` /
    /// `scalar`).
    pub fn backend_label(&self) -> String {
        match (self.is_simd(), self.lane_width()) {
            (_, 0) => "scalar".to_string(),
            (true, w) => format!("simd{w}"),
            (false, w) => format!("vec{w}"),
        }
    }
}

/// One entry of the v3 index footer: where a chunk frame lives and how it
/// was encoded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkIndexEntry {
    /// Byte offset of the chunk's [`CHUNK_TAG`] marker from the start of
    /// the container.
    pub offset: u64,
    /// Frame length in bytes (marker through the last section byte).
    pub frame_len: u64,
    /// Leading-dim extent of the chunk's slab.
    pub lead_extent: u64,
    pub meta: ChunkMeta,
}

/// One parity frame's location, from the footer-v2 parity table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParityIndexEntry {
    /// Byte offset of the frame's [`PARITY_TAG`] marker from the start of
    /// the container.
    pub offset: u64,
    /// Frame length in bytes (marker through the last payload byte).
    pub frame_len: u64,
}

/// Parity geometry of a footer-v2 container: the group size plus where
/// each group's parity frame lives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParityFooter {
    /// Data chunks per parity group (the last group may be shorter).
    pub group_size: u64,
    /// One entry per group, in group order.
    pub entries: Vec<ParityIndexEntry>,
}

/// One framed section.
#[derive(Clone, Debug)]
pub struct Section {
    pub tag: u8,
    pub raw_len: u64,
    pub payload: Vec<u8>,
}

fn kind_to_u8(k: CodesKind) -> u8 {
    match k {
        CodesKind::DualQuant => 0,
        CodesKind::Sz14 => 1,
    }
}

fn kind_from_u8(v: u8) -> Result<CodesKind> {
    match v {
        0 => Ok(CodesKind::DualQuant),
        1 => Ok(CodesKind::Sz14),
        _ => Err(VszError::format(format!("unknown codes kind {v}"))),
    }
}

fn pad_value_to_u8(v: PadValue) -> u8 {
    match v {
        PadValue::Zero => 0,
        PadValue::Min => 1,
        PadValue::Max => 2,
        PadValue::Avg => 3,
    }
}

fn pad_value_from_u8(v: u8) -> Result<PadValue> {
    Ok(match v {
        0 => PadValue::Zero,
        1 => PadValue::Min,
        2 => PadValue::Max,
        3 => PadValue::Avg,
        _ => return Err(VszError::format(format!("unknown pad value {v}"))),
    })
}

fn pad_gran_to_u8(g: PadGranularity) -> u8 {
    match g {
        PadGranularity::Global => 0,
        PadGranularity::Block => 1,
        PadGranularity::Edge => 2,
    }
}

fn pad_gran_from_u8(v: u8) -> Result<PadGranularity> {
    Ok(match v {
        0 => PadGranularity::Global,
        1 => PadGranularity::Block,
        2 => PadGranularity::Edge,
        _ => return Err(VszError::format(format!("unknown pad granularity {v}"))),
    })
}

/// Append the header fields shared by both container versions (everything
/// between the version word and the version-specific framing).
fn write_header_fields(out: &mut Vec<u8>, header: &Header) {
    out.push(header.dims.ndim as u8);
    out.push(kind_to_u8(header.codes_kind));
    for d in header.dims.shape {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    out.extend_from_slice(&header.eb.to_bits().to_le_bytes());
    out.extend_from_slice(&header.radius.to_le_bytes());
    out.extend_from_slice(&header.block_size.to_le_bytes());
    out.push(pad_value_to_u8(header.padding.value));
    out.push(pad_gran_to_u8(header.padding.granularity));
}

/// Parse the shared header fields (inverse of [`write_header_fields`]).
fn read_header_fields(c: &mut Cursor) -> Result<Header> {
    let ndim = c.u8().ok_or_else(|| VszError::format("truncated ndim"))? as usize;
    if !(1..=3).contains(&ndim) {
        return Err(VszError::format(format!("bad ndim {ndim}")));
    }
    let codes_kind = kind_from_u8(c.u8().ok_or_else(|| VszError::format("truncated kind"))?)?;
    let mut shape = [1usize; 3];
    for s in shape.iter_mut() {
        let d = c.u64().ok_or_else(|| VszError::format("truncated dims"))?;
        // bound each axis so a corrupt header cannot drive allocations into
        // overflow/OOM territory before any payload check runs
        if d == 0 || d > 1 << 40 {
            return Err(VszError::format(format!("implausible dimension {d}")));
        }
        *s = d as usize;
    }
    let dims = Dims { shape, ndim };
    let total = (dims.shape[0] as u128) * (dims.shape[1] as u128) * (dims.shape[2] as u128);
    if total > 1 << 42 {
        return Err(VszError::format("implausible field size"));
    }
    let eb = c.f64().ok_or_else(|| VszError::format("truncated eb"))?;
    if !(eb.is_finite() && eb > 0.0) {
        return Err(VszError::format("invalid error bound"));
    }
    let radius = c.u16().ok_or_else(|| VszError::format("truncated radius"))?;
    let block_size = c.u32().ok_or_else(|| VszError::format("truncated block size"))?;
    let pv = pad_value_from_u8(c.u8().ok_or_else(|| VszError::format("truncated pad value"))?)?;
    let pg = pad_gran_from_u8(c.u8().ok_or_else(|| VszError::format("truncated pad gran"))?)?;
    Ok(Header { dims, codes_kind, eb, radius, block_size, padding: PaddingPolicy::new(pv, pg) })
}

/// Append one framed section (shared by v1 and v2 containers).
pub fn write_section(out: &mut Vec<u8>, s: &Section) {
    out.push(s.tag);
    put_uvarint(out, s.raw_len);
    put_uvarint(out, s.payload.len() as u64);
    out.extend_from_slice(&crc32(&s.payload).to_le_bytes());
    out.extend_from_slice(&s.payload);
}

/// Parse and CRC-check one framed section.
pub fn read_section(c: &mut Cursor) -> Result<Section> {
    let tag = c.u8().ok_or_else(|| VszError::format("truncated section tag"))?;
    let raw_len = c.uvarint().ok_or_else(|| VszError::format("truncated raw_len"))?;
    let enc_len = c.uvarint().ok_or_else(|| VszError::format("truncated enc_len"))? as usize;
    let crc = c.u32().ok_or_else(|| VszError::format("truncated crc"))?;
    let payload = c
        .take(enc_len)
        .ok_or_else(|| VszError::format("truncated section payload"))?
        .to_vec();
    if crc32(&payload) != crc {
        return Err(VszError::Integrity(format!("section {tag}: crc mismatch")));
    }
    Ok(Section { tag, raw_len, payload })
}

/// Serialize a v1 container.
pub fn write_container(header: &Header, sections: &[Section]) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + sections.iter().map(|s| s.payload.len() + 16).sum::<usize>());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    write_header_fields(&mut out, header);
    out.push(sections.len() as u8);
    for s in sections {
        write_section(&mut out, s);
    }
    out
}

/// Parse and integrity-check a v1 container.
pub fn read_container(data: &[u8]) -> Result<(Header, Vec<Section>)> {
    let mut c = Cursor::new(data);
    let magic = c.take(4).ok_or_else(|| VszError::format("truncated magic"))?;
    if magic == MAGIC2 || magic == MAGIC3 {
        return Err(VszError::format(
            "chunked (VSZ2/VSZ3) container: use the streaming decoder (stream module)",
        ));
    }
    if magic != MAGIC {
        return Err(VszError::format("bad magic (not a .vsz container)"));
    }
    let version = c.u16().ok_or_else(|| VszError::format("truncated version"))?;
    if version != VERSION {
        return Err(VszError::format(format!("unsupported version {version}")));
    }
    let header = read_header_fields(&mut c)?;
    let n_sections = c.u8().ok_or_else(|| VszError::format("truncated section count"))? as usize;
    let mut sections = Vec::with_capacity(n_sections);
    for _ in 0..n_sections {
        sections.push(read_section(&mut c)?);
    }
    Ok((header, sections))
}

/// True when `data` starts with a chunked streaming magic (v2 or v3).
pub fn is_chunked_container(data: &[u8]) -> bool {
    data.len() >= 4 && (&data[..4] == MAGIC2 || &data[..4] == MAGIC3)
}

/// Parse just the field dims from a container's leading bytes — single-shot
/// (v1) or chunked (v2/v3) — without touching sections or payload. Lets the
/// server bound a request's decoded-output memory before admitting it.
pub fn peek_dims(data: &[u8]) -> Result<Dims> {
    if is_chunked_container(data) {
        if data.len() < STREAM_HEADER_LEN {
            return Err(VszError::format("truncated stream header"));
        }
        return Ok(read_stream_header(&data[..STREAM_HEADER_LEN])?.header.dims);
    }
    let mut c = Cursor::new(data);
    match c.take(4) {
        Some(m) if m == MAGIC => {}
        Some(_) => return Err(VszError::format("bad magic (not a .vsz container)")),
        None => return Err(VszError::format("truncated magic")),
    }
    let version = c.u16().ok_or_else(|| VszError::format("truncated version"))?;
    if version != VERSION {
        return Err(VszError::format(format!("unsupported version {version}")));
    }
    Ok(read_header_fields(&mut c)?.dims)
}

/// Serialize a v2/v3 stream header (fixed [`STREAM_HEADER_LEN`] bytes);
/// the magic and version word follow `sh.version`. Errors on any other
/// version (the `StreamHeader` fields are public, so a hand-built header
/// must not panic the format layer).
pub fn write_stream_header(sh: &StreamHeader) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(STREAM_HEADER_LEN);
    match sh.version {
        VERSION2 => out.extend_from_slice(MAGIC2),
        VERSION3 => out.extend_from_slice(MAGIC3),
        v => return Err(VszError::config(format!("unsupported stream version {v}"))),
    }
    out.extend_from_slice(&sh.version.to_le_bytes());
    write_header_fields(&mut out, &sh.header);
    out.extend_from_slice(&sh.chunk_span.to_le_bytes());
    debug_assert_eq!(out.len(), STREAM_HEADER_LEN);
    Ok(out)
}

/// Parse a v2/v3 stream header from the first [`STREAM_HEADER_LEN`] bytes.
pub fn read_stream_header(data: &[u8]) -> Result<StreamHeader> {
    let mut c = Cursor::new(data);
    let magic = c.take(4).ok_or_else(|| VszError::format("truncated magic"))?;
    if magic != MAGIC2 && magic != MAGIC3 {
        return Err(VszError::format("bad magic (not a chunked .vsz container)"));
    }
    let version = c.u16().ok_or_else(|| VszError::format("truncated version"))?;
    let expect = if magic == MAGIC2 { VERSION2 } else { VERSION3 };
    if version != expect {
        return Err(VszError::format(format!("stream version {version} does not match its magic")));
    }
    let header = read_header_fields(&mut c)?;
    let chunk_span = c.u64().ok_or_else(|| VszError::format("truncated chunk span"))?;
    if chunk_span == 0 {
        return Err(VszError::format("zero chunk span"));
    }
    Ok(StreamHeader { header, chunk_span, version })
}

/// Append one chunk frame (marker + geometry + sections). `meta` must be
/// `Some` exactly for v3 containers (per-chunk config bytes).
pub fn write_chunk_frame(
    out: &mut Vec<u8>,
    chunk_index: u64,
    lead_extent: u64,
    meta: Option<ChunkMeta>,
    sections: &[Section],
) {
    out.push(CHUNK_TAG);
    put_uvarint(out, chunk_index);
    put_uvarint(out, lead_extent);
    if let Some(m) = meta {
        put_uvarint(out, m.block_size as u64);
        out.push(m.width);
    }
    out.push(sections.len() as u8);
    for s in sections {
        write_section(out, s);
    }
}

/// A parsed v2/v3 frame: one chunk, one parity frame (v3 parity layer
/// only), or the end-of-stream trailer. `meta` is `Some` for v3 chunk
/// frames, `None` for v2 (config comes from the stream header then).
#[derive(Debug)]
pub enum Frame {
    Chunk { index: u64, lead_extent: u64, meta: Option<ChunkMeta>, sections: Vec<Section> },
    /// XOR of `members` zero-padded data frames (see the module doc's
    /// padding rule); `payload` is CRC-verified on parse.
    Parity { group: u64, members: u64, payload: Vec<u8> },
    End { n_chunks: u64 },
}

/// Append one parity frame (marker + group geometry + CRC'd payload).
pub fn write_parity_frame(out: &mut Vec<u8>, group: u64, members: u64, payload: &[u8]) {
    out.push(PARITY_TAG);
    put_uvarint(out, group);
    put_uvarint(out, members);
    put_uvarint(out, payload.len() as u64);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// Parse the next frame at the cursor (chunk or trailer). `version` selects
/// the chunk-frame layout (v3 frames carry per-chunk config bytes).
pub fn read_frame(c: &mut Cursor, version: u16) -> Result<Frame> {
    let marker = c.u8().ok_or_else(|| VszError::format("truncated frame marker"))?;
    match marker {
        CHUNK_TAG => {
            let index = c.uvarint().ok_or_else(|| VszError::format("truncated chunk index"))?;
            let lead_extent =
                c.uvarint().ok_or_else(|| VszError::format("truncated chunk extent"))?;
            if lead_extent == 0 {
                return Err(VszError::format("empty chunk"));
            }
            let meta = if version >= VERSION3 {
                let block_size = check_block_size(
                    c.uvarint().ok_or_else(|| VszError::format("truncated chunk block size"))?,
                )?;
                let width = c.u8().ok_or_else(|| VszError::format("truncated chunk width"))?;
                Some(ChunkMeta { block_size, width })
            } else {
                None
            };
            let n_sections =
                c.u8().ok_or_else(|| VszError::format("truncated chunk section count"))? as usize;
            let mut sections = Vec::with_capacity(n_sections);
            for _ in 0..n_sections {
                sections.push(read_section(c)?);
            }
            Ok(Frame::Chunk { index, lead_extent, meta, sections })
        }
        PARITY_TAG => {
            let group = c.uvarint().ok_or_else(|| VszError::format("truncated parity group"))?;
            let members =
                c.uvarint().ok_or_else(|| VszError::format("truncated parity members"))?;
            if members == 0 {
                return Err(VszError::format("empty parity group"));
            }
            let len =
                c.uvarint().ok_or_else(|| VszError::format("truncated parity length"))? as usize;
            let crc = c.u32().ok_or_else(|| VszError::format("truncated parity crc"))?;
            let payload = c
                .take(len)
                .ok_or_else(|| VszError::format("truncated parity payload"))?
                .to_vec();
            if crc32(&payload) != crc {
                return Err(VszError::Integrity(format!("parity group {group}: crc mismatch")));
            }
            Ok(Frame::Parity { group, members, payload })
        }
        END_TAG => {
            let n_chunks = c.uvarint().ok_or_else(|| VszError::format("truncated trailer"))?;
            let crc = c.u32().ok_or_else(|| VszError::format("truncated trailer crc"))?;
            if crc32(&n_chunks.to_le_bytes()) != crc {
                return Err(VszError::Integrity("trailer crc mismatch".into()));
            }
            Ok(Frame::End { n_chunks })
        }
        other => Err(VszError::format(format!("unknown frame marker {other:#x}"))),
    }
}

/// Append the v3 index footer: tag, entry table, CRC, and the trailing
/// length word that makes the footer discoverable from EOF.
pub fn write_index_footer(out: &mut Vec<u8>, entries: &[ChunkIndexEntry]) {
    let start = out.len();
    out.push(INDEX_TAG);
    put_uvarint(out, entries.len() as u64);
    for e in entries {
        put_uvarint(out, e.offset);
        put_uvarint(out, e.frame_len);
        put_uvarint(out, e.lead_extent);
        put_uvarint(out, e.meta.block_size as u64);
        out.push(e.meta.width);
    }
    let crc = crc32(&out[start..]);
    out.extend_from_slice(&crc.to_le_bytes());
    let len = (out.len() - start) as u32; // INDEX_TAG through the crc
    out.extend_from_slice(&len.to_le_bytes());
}

/// Append the footer v2: like [`write_index_footer`] but tagged
/// [`INDEX_TAG2`] and carrying the parity group size plus the parity-frame
/// table. Written only for containers that actually have parity frames —
/// parity-less output keeps the plain v1 footer byte-for-byte.
pub fn write_index_footer_v2(
    out: &mut Vec<u8>,
    entries: &[ChunkIndexEntry],
    parity: &ParityFooter,
) {
    let start = out.len();
    out.push(INDEX_TAG2);
    put_uvarint(out, parity.group_size);
    put_uvarint(out, entries.len() as u64);
    for e in entries {
        put_uvarint(out, e.offset);
        put_uvarint(out, e.frame_len);
        put_uvarint(out, e.lead_extent);
        put_uvarint(out, e.meta.block_size as u64);
        out.push(e.meta.width);
    }
    put_uvarint(out, parity.entries.len() as u64);
    for p in &parity.entries {
        put_uvarint(out, p.offset);
        put_uvarint(out, p.frame_len);
    }
    let crc = crc32(&out[start..]);
    out.extend_from_slice(&crc.to_le_bytes());
    let len = (out.len() - start) as u32; // INDEX_TAG2 through the crc
    out.extend_from_slice(&len.to_le_bytes());
}

/// Parse the shared entry table of either footer version.
fn read_index_entries(c: &mut Cursor, body_len: usize) -> Result<Vec<ChunkIndexEntry>> {
    let n = c.uvarint().ok_or_else(|| VszError::format("truncated index count"))?;
    // each entry is at least 5 bytes, so the count is bounded by the
    // CRC-verified footer length — no forged-length allocation possible
    if n == 0 || n as usize > body_len / 5 + 1 {
        return Err(VszError::format(format!("implausible index chunk count {n}")));
    }
    let mut entries = Vec::with_capacity(n as usize);
    for k in 0..n {
        let trunc = || VszError::format(format!("truncated index entry {k}"));
        let offset = c.uvarint().ok_or_else(trunc)?;
        let frame_len = c.uvarint().ok_or_else(trunc)?;
        let lead_extent = c.uvarint().ok_or_else(trunc)?;
        let block_size = check_block_size(c.uvarint().ok_or_else(trunc)?)?;
        let width = c.u8().ok_or_else(trunc)?;
        entries.push(ChunkIndexEntry {
            offset,
            frame_len,
            lead_extent,
            meta: ChunkMeta { block_size, width },
        });
    }
    Ok(entries)
}

/// Parse and CRC-check a v3 index footer (footer v1 only — the pre-parity
/// layout). `bytes` is the `footer_len`-byte slice preceding the trailing
/// length word (INDEX_TAG through the crc).
pub fn read_index_footer(bytes: &[u8]) -> Result<Vec<ChunkIndexEntry>> {
    match read_index_footer_any(bytes)? {
        (entries, None) => Ok(entries),
        (_, Some(_)) => Err(VszError::format(
            "parity-extended index footer: this read path does not support parity",
        )),
    }
}

/// Parse and CRC-check either index footer version, dispatching on the
/// leading tag byte: `0xD3` → footer v1 (no parity), `0xD4` → footer v2
/// (parity geometry in the second return slot).
pub fn read_index_footer_any(
    bytes: &[u8],
) -> Result<(Vec<ChunkIndexEntry>, Option<ParityFooter>)> {
    if bytes.len() < 1 + 1 + 4 {
        return Err(VszError::format("truncated index footer"));
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    if crc32(body) != crc {
        return Err(VszError::Integrity("index footer crc mismatch".into()));
    }
    let mut c = Cursor::new(body);
    let tag = c.u8();
    let parity_tagged = match tag {
        Some(INDEX_TAG) => false,
        Some(INDEX_TAG2) => true,
        _ => return Err(VszError::format("bad index footer tag")),
    };
    let group_size = if parity_tagged {
        let g = c.uvarint().ok_or_else(|| VszError::format("truncated parity group size"))?;
        if g == 0 {
            return Err(VszError::format("zero parity group size"));
        }
        g
    } else {
        0
    };
    let entries = read_index_entries(&mut c, body.len())?;
    let parity = if parity_tagged {
        let np = c.uvarint().ok_or_else(|| VszError::format("truncated parity count"))?;
        // each parity entry is at least 2 bytes — same forged-count guard
        if np as usize > body.len() / 2 + 1 {
            return Err(VszError::format(format!("implausible parity count {np}")));
        }
        let expect = (entries.len() as u64).div_ceil(group_size);
        if np != expect {
            return Err(VszError::format(format!(
                "parity table has {np} entries; {} chunks in groups of {group_size} need {expect}",
                entries.len()
            )));
        }
        let mut pe = Vec::with_capacity(np as usize);
        for k in 0..np {
            let trunc = || VszError::format(format!("truncated parity entry {k}"));
            let offset = c.uvarint().ok_or_else(trunc)?;
            let frame_len = c.uvarint().ok_or_else(trunc)?;
            pe.push(ParityIndexEntry { offset, frame_len });
        }
        Some(ParityFooter { group_size, entries: pe })
    } else {
        None
    };
    if c.remaining() != 0 {
        return Err(VszError::format("trailing bytes in index footer"));
    }
    Ok((entries, parity))
}

/// Append the end-of-stream trailer.
pub fn write_trailer(out: &mut Vec<u8>, n_chunks: u64) {
    out.push(END_TAG);
    put_uvarint(out, n_chunks);
    out.extend_from_slice(&crc32(&n_chunks.to_le_bytes()).to_le_bytes());
}

/// Find a section by tag.
pub fn find_section<'a>(sections: &'a [Section], t: u8) -> Result<&'a Section> {
    sections
        .iter()
        .find(|s| s.tag == t)
        .ok_or_else(|| VszError::format(format!("missing section {t}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_header() -> Header {
        Header {
            dims: Dims::d2(180, 360),
            codes_kind: CodesKind::DualQuant,
            eb: 1e-4,
            radius: 512,
            block_size: 16,
            padding: PaddingPolicy::new(PadValue::Avg, PadGranularity::Global),
        }
    }

    #[test]
    fn roundtrip_header_and_sections() {
        let h = sample_header();
        let secs = vec![
            Section { tag: tag::CODES, raw_len: 1000, payload: vec![1, 2, 3, 4] },
            Section { tag: tag::OUTLIER_POS, raw_len: 5, payload: vec![9] },
            Section { tag: tag::PAD_SCALARS, raw_len: 4, payload: vec![0, 0, 128, 63] },
        ];
        let blob = write_container(&h, &secs);
        let (h2, secs2) = read_container(&blob).unwrap();
        assert_eq!(h, h2);
        assert_eq!(secs2.len(), 3);
        assert_eq!(secs2[0].payload, vec![1, 2, 3, 4]);
        assert_eq!(secs2[0].raw_len, 1000);
        assert_eq!(find_section(&secs2, tag::OUTLIER_POS).unwrap().payload, vec![9]);
        assert!(find_section(&secs2, tag::OUTLIER_VAL).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut blob = write_container(&sample_header(), &[]);
        blob[0] = b'X';
        assert!(matches!(read_container(&blob), Err(VszError::Format(_))));
    }

    #[test]
    fn rejects_corrupt_payload() {
        let secs =
            vec![Section { tag: tag::CODES, raw_len: 8, payload: vec![1, 2, 3, 4, 5, 6] }];
        let mut blob = write_container(&sample_header(), &secs);
        let n = blob.len();
        blob[n - 1] ^= 0xFF;
        assert!(matches!(read_container(&blob), Err(VszError::Integrity(_))));
    }

    #[test]
    fn rejects_truncation_anywhere() {
        let secs = vec![Section { tag: tag::CODES, raw_len: 8, payload: vec![7; 32] }];
        let blob = write_container(&sample_header(), &secs);
        for cut in [3usize, 5, 8, 20, blob.len() - 1] {
            assert!(read_container(&blob[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn rejects_nonsense_eb_and_ndim() {
        let mut h = sample_header();
        h.eb = -1.0;
        let blob = write_container(&h, &[]);
        assert!(read_container(&blob).is_err());
        let mut blob2 = write_container(&sample_header(), &[]);
        blob2[6] = 7; // ndim byte
        assert!(read_container(&blob2).is_err());
    }

    #[test]
    fn sz14_kind_roundtrips() {
        let mut h = sample_header();
        h.codes_kind = CodesKind::Sz14;
        let (h2, _) = read_container(&write_container(&h, &[])).unwrap();
        assert_eq!(h2.codes_kind, CodesKind::Sz14);
    }

    // --------------------------------------------------- v2/v3 framing

    fn sample_stream_header() -> StreamHeader {
        StreamHeader { header: sample_header(), chunk_span: 32, version: VERSION2 }
    }

    fn sample_stream_header_v3() -> StreamHeader {
        StreamHeader { version: VERSION3, ..sample_stream_header() }
    }

    #[test]
    fn peek_dims_reads_every_container_flavor() {
        // chunked v2/v3: dims come from the fixed-size stream header
        for sh in [sample_stream_header(), sample_stream_header_v3()] {
            let bytes = write_stream_header(&sh).unwrap();
            assert_eq!(peek_dims(&bytes).unwrap(), sh.header.dims);
            assert!(peek_dims(&bytes[..10]).is_err(), "truncated stream header");
        }
        // single-shot v1: dims come from the header fields after the magic
        let header = sample_header();
        let v1 = write_container(&header, &[]);
        assert_eq!(peek_dims(&v1).unwrap(), header.dims);
        assert!(peek_dims(b"XXXXXXXXXXXX").is_err(), "bad magic");
        assert!(peek_dims(b"XX").is_err(), "truncated magic");
    }

    #[test]
    fn stream_header_roundtrip_both_versions() {
        for sh in [sample_stream_header(), sample_stream_header_v3()] {
            let bytes = write_stream_header(&sh).unwrap();
            assert_eq!(bytes.len(), STREAM_HEADER_LEN);
            assert!(is_chunked_container(&bytes));
            let back = read_stream_header(&bytes).unwrap();
            assert_eq!(sh, back);
        }
        // a version the format does not know is an error, not a panic
        let bad = StreamHeader { version: 7, ..sample_stream_header() };
        assert!(write_stream_header(&bad).is_err());
    }

    #[test]
    fn version_magic_mismatch_rejected() {
        // a VSZ3 magic with a version word of 2 (or vice versa) is a
        // forgery, not a valid container
        let mut bytes = write_stream_header(&sample_stream_header_v3()).unwrap();
        bytes[4..6].copy_from_slice(&VERSION2.to_le_bytes());
        assert!(read_stream_header(&bytes).is_err());
    }

    #[test]
    fn v1_reader_rejects_chunked_containers_cleanly() {
        for sh in [sample_stream_header(), sample_stream_header_v3()] {
            let bytes = write_stream_header(&sh).unwrap();
            let err = read_container(&bytes).unwrap_err();
            assert!(err.to_string().contains("stream"), "{err}");
        }
    }

    #[test]
    fn chunk_frames_and_trailer_roundtrip() {
        let mut out = write_stream_header(&sample_stream_header()).unwrap();
        let secs = vec![
            Section { tag: tag::CODES, raw_len: 64, payload: vec![5; 10] },
            Section { tag: tag::PAD_SCALARS, raw_len: 4, payload: vec![1, 2, 3, 4] },
        ];
        write_chunk_frame(&mut out, 0, 32, None, &secs);
        write_chunk_frame(&mut out, 1, 7, None, &secs);
        write_trailer(&mut out, 2);

        let mut c = Cursor::new(&out[STREAM_HEADER_LEN..]);
        match read_frame(&mut c, VERSION2).unwrap() {
            Frame::Chunk { index, lead_extent, meta, sections } => {
                assert_eq!(index, 0);
                assert_eq!(lead_extent, 32);
                assert_eq!(meta, None);
                assert_eq!(sections.len(), 2);
                assert_eq!(sections[0].payload, vec![5; 10]);
            }
            other => panic!("expected chunk, got {other:?}"),
        }
        match read_frame(&mut c, VERSION2).unwrap() {
            Frame::Chunk { index, lead_extent, .. } => {
                assert_eq!(index, 1);
                assert_eq!(lead_extent, 7);
            }
            other => panic!("expected chunk, got {other:?}"),
        }
        match read_frame(&mut c, VERSION2).unwrap() {
            Frame::End { n_chunks } => assert_eq!(n_chunks, 2),
            other => panic!("expected end, got {other:?}"),
        }
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn v3_chunk_frame_carries_per_chunk_config() {
        let mut out = Vec::new();
        let secs = vec![Section { tag: tag::CODES, raw_len: 64, payload: vec![5; 10] }];
        let meta = ChunkMeta { block_size: 32, width: 16 };
        write_chunk_frame(&mut out, 3, 64, Some(meta), &secs);
        let mut c = Cursor::new(&out);
        match read_frame(&mut c, VERSION3).unwrap() {
            Frame::Chunk { index, lead_extent, meta: m, sections } => {
                assert_eq!(index, 3);
                assert_eq!(lead_extent, 64);
                assert_eq!(m, Some(meta));
                assert_eq!(sections.len(), 1);
            }
            other => panic!("expected chunk, got {other:?}"),
        }
        assert_eq!(c.remaining(), 0);
        // a v2 parse of the same bytes must not silently succeed with
        // garbage: the config bytes land in the section count / section
        // frames and fail the walk
        let mut c2 = Cursor::new(&out);
        assert!(read_frame(&mut c2, VERSION2).is_err());
    }

    #[test]
    fn chunk_frame_crc_detects_flips() {
        let mut out = Vec::new();
        let secs = vec![Section { tag: tag::CODES, raw_len: 16, payload: vec![9; 16] }];
        write_chunk_frame(&mut out, 0, 8, None, &secs);
        let n = out.len();
        out[n - 3] ^= 0x40;
        let mut c = Cursor::new(&out);
        assert!(matches!(read_frame(&mut c, VERSION2), Err(VszError::Integrity(_))));
    }

    #[test]
    fn trailer_crc_detects_flips() {
        let mut out = Vec::new();
        write_trailer(&mut out, 5);
        out[1] ^= 0x01; // n_chunks varint
        let mut c = Cursor::new(&out);
        assert!(read_frame(&mut c, VERSION2).is_err());
    }

    #[test]
    fn unknown_marker_rejected() {
        let mut c = Cursor::new(&[0x7Fu8, 0, 0][..]);
        assert!(read_frame(&mut c, VERSION2).is_err());
    }

    // ------------------------------------------------------ index footer

    fn sample_entries() -> Vec<ChunkIndexEntry> {
        vec![
            ChunkIndexEntry {
                offset: STREAM_HEADER_LEN as u64,
                frame_len: 300,
                lead_extent: 32,
                meta: ChunkMeta { block_size: 16, width: 8 },
            },
            ChunkIndexEntry {
                offset: STREAM_HEADER_LEN as u64 + 300,
                frame_len: 123,
                lead_extent: 7,
                meta: ChunkMeta { block_size: 32, width: 16 },
            },
        ]
    }

    #[test]
    fn index_footer_roundtrip_and_length_suffix() {
        let entries = sample_entries();
        let mut out = vec![0xAAu8; 17]; // footer appends after arbitrary payload
        write_index_footer(&mut out, &entries);
        let len =
            u32::from_le_bytes(out[out.len() - 4..].try_into().unwrap()) as usize;
        let start = out.len() - 4 - len;
        assert_eq!(out[start], INDEX_TAG);
        let back = read_index_footer(&out[start..out.len() - 4]).unwrap();
        assert_eq!(back, entries);
    }

    #[test]
    fn index_footer_flips_rejected_everywhere() {
        let entries = sample_entries();
        let mut out = Vec::new();
        write_index_footer(&mut out, &entries);
        let len = u32::from_le_bytes(out[out.len() - 4..].try_into().unwrap()) as usize;
        let body_end = out.len() - 4;
        for at in 0..body_end {
            let mut bad = out.clone();
            bad[at] ^= 0x11;
            assert!(
                read_index_footer(&bad[body_end - len..body_end]).is_err(),
                "flip at {at} accepted"
            );
        }
    }

    #[test]
    fn index_footer_truncation_rejected() {
        let mut out = Vec::new();
        write_index_footer(&mut out, &sample_entries());
        let body_end = out.len() - 4;
        for cut in [0, 1, 3, body_end / 2, body_end - 1] {
            assert!(read_index_footer(&out[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn index_footer_rejects_bad_block_size() {
        let mut entries = sample_entries();
        entries[1].meta.block_size = 1; // below the decoder's floor
        let mut out = Vec::new();
        write_index_footer(&mut out, &entries);
        let body_end = out.len() - 4;
        assert!(read_index_footer(&out[..body_end]).is_err());
    }

    // ---------------------------------------------- parity frames + footer v2

    #[test]
    fn parity_frame_roundtrip_and_crc() {
        let payload = vec![0x5Au8, 0, 0xFF, 7, 1];
        let mut out = Vec::new();
        write_parity_frame(&mut out, 3, 8, &payload);
        for version in [VERSION2, VERSION3] {
            let mut c = Cursor::new(&out);
            match read_frame(&mut c, version).unwrap() {
                Frame::Parity { group, members, payload: p } => {
                    assert_eq!(group, 3);
                    assert_eq!(members, 8);
                    assert_eq!(p, payload);
                }
                other => panic!("expected parity, got {other:?}"),
            }
            assert_eq!(c.remaining(), 0);
        }
        // flips in the length, crc or payload are caught by the frame's own
        // CRC (group/members geometry is redundantly covered by the
        // CRC-protected footer v2 instead)
        for at in 3..out.len() {
            let mut bad = out.clone();
            bad[at] ^= 0x20;
            let mut c = Cursor::new(&bad);
            assert!(read_frame(&mut c, VERSION3).is_err(), "flip at {at} accepted");
        }
    }

    fn sample_parity() -> ParityFooter {
        ParityFooter {
            group_size: 8,
            entries: vec![ParityIndexEntry { offset: 423, frame_len: 310 }],
        }
    }

    #[test]
    fn footer_v2_roundtrips_with_parity_geometry() {
        let entries = sample_entries();
        let parity = sample_parity();
        let mut out = vec![0x33u8; 9]; // footer appends after arbitrary payload
        write_index_footer_v2(&mut out, &entries, &parity);
        let len = u32::from_le_bytes(out[out.len() - 4..].try_into().unwrap()) as usize;
        let start = out.len() - 4 - len;
        assert_eq!(out[start], INDEX_TAG2);
        let (back, p) = read_index_footer_any(&out[start..out.len() - 4]).unwrap();
        assert_eq!(back, entries);
        assert_eq!(p, Some(parity));
        // the pre-parity reader rejects the v2 tag rather than misparse it
        assert!(read_index_footer(&out[start..out.len() - 4]).is_err());
    }

    #[test]
    fn footer_v2_flips_rejected_everywhere() {
        let mut out = Vec::new();
        write_index_footer_v2(&mut out, &sample_entries(), &sample_parity());
        let body_end = out.len() - 4;
        for at in 0..body_end {
            let mut bad = out.clone();
            bad[at] ^= 0x11;
            assert!(read_index_footer_any(&bad[..body_end]).is_err(), "flip at {at} accepted");
        }
        for cut in [0, 1, 3, body_end / 2, body_end - 1] {
            assert!(read_index_footer_any(&out[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn footer_v2_group_geometry_must_be_consistent() {
        // 2 chunks in groups of 8 need exactly 1 parity entry; 2 is a forgery
        let bad = ParityFooter {
            group_size: 8,
            entries: vec![
                ParityIndexEntry { offset: 423, frame_len: 310 },
                ParityIndexEntry { offset: 733, frame_len: 10 },
            ],
        };
        let mut out = Vec::new();
        write_index_footer_v2(&mut out, &sample_entries(), &bad);
        let body_end = out.len() - 4;
        let err = read_index_footer_any(&out[..body_end]).unwrap_err();
        assert!(err.to_string().contains("parity table"), "{err}");
    }

    #[test]
    fn footer_dispatch_reads_v1_as_parityless() {
        let entries = sample_entries();
        let mut out = Vec::new();
        write_index_footer(&mut out, &entries);
        let body_end = out.len() - 4;
        let (back, p) = read_index_footer_any(&out[..body_end]).unwrap();
        assert_eq!(back, entries);
        assert_eq!(p, None);
    }
}

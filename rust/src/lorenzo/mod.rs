//! Lorenzo prediction (Ibarria et al. [23]) over halo-buffered blocks.
//!
//! The predictor estimates each element from its already-visited neighbours
//! by inclusion-exclusion over the axis subsets:
//!   1D: p = W
//!   2D: p = W + N − NW
//!   3D: p = (W + N + U) − (NW + NU + WU) + NWU
//! Working on a [`HaloBlock`] makes every neighbour read branch-free: border
//! neighbours land in the halo planes, which hold the padding scalar.
//!
//! This module provides the *scalar* predictor shared by the pSZ baseline,
//! the SZ-1.4 baseline and the decompressor; the vectorized backend inlines
//! its own lane-parallel version (bit-identical, tested in `quant`).

use crate::blocks::BlockShape;

/// Scalar Lorenzo prediction at interior coordinate `c` of a halo buffer.
/// `buf` has side `bs+1` per axis; `c` is the *interior* coordinate (0-based
/// within the block); the halo offset (+1) is applied here.
#[inline]
pub fn predict_halo(buf: &[f32], shape: BlockShape, c: [usize; 3]) -> f32 {
    let side = shape.halo_side();
    match shape.ndim {
        1 => buf[c[0]], // (c0+1)-1
        2 => {
            let i = c[0] + 1;
            let j = c[1] + 1;
            let w = buf[i * side + (j - 1)];
            let n = buf[(i - 1) * side + j];
            let nw = buf[(i - 1) * side + (j - 1)];
            w + n - nw
        }
        3 => {
            let k = c[0] + 1;
            let i = c[1] + 1;
            let j = c[2] + 1;
            let at = |k: usize, i: usize, j: usize| buf[(k * side + i) * side + j];
            let w = at(k, i, j - 1);
            let n = at(k, i - 1, j);
            let u = at(k - 1, i, j);
            let nw = at(k, i - 1, j - 1);
            let wu = at(k - 1, i, j - 1);
            let nu = at(k - 1, i - 1, j);
            let nwu = at(k - 1, i - 1, j - 1);
            (w + n + u) - (nw + nu + wu) + nwu
        }
        _ => unreachable!(),
    }
}

/// Iterate interior coordinates of a block in row-major order, calling
/// `f(linear_index_within_block, coords)`.
#[inline]
pub fn for_each_coord(shape: BlockShape, mut f: impl FnMut(usize, [usize; 3])) {
    let bs = shape.bs;
    match shape.ndim {
        1 => {
            for x in 0..bs {
                f(x, [x, 0, 0]);
            }
        }
        2 => {
            let mut l = 0;
            for i in 0..bs {
                for j in 0..bs {
                    f(l, [i, j, 0]);
                    l += 1;
                }
            }
        }
        3 => {
            let mut l = 0;
            for k in 0..bs {
                for i in 0..bs {
                    for j in 0..bs {
                        f(l, [k, i, j]);
                        l += 1;
                    }
                }
            }
        }
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::HaloBlock;

    #[test]
    fn predict_1d_is_west_neighbor() {
        let shape = BlockShape::new(1, 4);
        let mut h = HaloBlock::new(shape);
        h.fill_halo(|_| 7.0);
        h.load_interior(&[1.0, 2.0, 3.0, 4.0], |x| x);
        assert_eq!(predict_halo(&h.buf, shape, [0, 0, 0]), 7.0); // pad
        assert_eq!(predict_halo(&h.buf, shape, [1, 0, 0]), 1.0);
        assert_eq!(predict_halo(&h.buf, shape, [3, 0, 0]), 3.0);
    }

    #[test]
    fn predict_2d_plane_is_exact_for_bilinear() {
        // f(i,j) = 3 + 2i + 5j is predicted exactly by W+N-NW everywhere
        // (away from padding); check interior element (1,1)..(3,3).
        let bs = 4;
        let shape = BlockShape::new(2, bs);
        let mut h = HaloBlock::new(shape);
        h.fill_halo(|_| 0.0);
        let block: Vec<f32> = (0..bs * bs)
            .map(|l| {
                let (i, j) = (l / bs, l % bs);
                3.0 + 2.0 * i as f32 + 5.0 * j as f32
            })
            .collect();
        h.load_interior(&block, |x| x);
        for i in 1..bs {
            for j in 1..bs {
                let p = predict_halo(&h.buf, shape, [i, j, 0]);
                let actual = 3.0 + 2.0 * i as f32 + 5.0 * j as f32;
                assert!((p - actual).abs() < 1e-5, "({i},{j}): {p} vs {actual}");
            }
        }
    }

    #[test]
    fn predict_3d_exact_for_trilinear() {
        let bs = 3;
        let shape = BlockShape::new(3, bs);
        let mut h = HaloBlock::new(shape);
        h.fill_halo(|_| 0.0);
        let f = |k: usize, i: usize, j: usize| 1.0 + 2.0 * k as f32 - 3.0 * i as f32 + 0.5 * j as f32;
        let mut block = vec![0.0f32; bs * bs * bs];
        for_each_coord(shape, |l, c| block[l] = f(c[0], c[1], c[2]));
        h.load_interior(&block, |x| x);
        for k in 1..bs {
            for i in 1..bs {
                for j in 1..bs {
                    let p = predict_halo(&h.buf, shape, [k, i, j]);
                    assert!((p - f(k, i, j)).abs() < 1e-4);
                }
            }
        }
    }

    #[test]
    fn coord_iteration_order_is_row_major() {
        let shape = BlockShape::new(3, 2);
        let mut seen = Vec::new();
        for_each_coord(shape, |l, c| seen.push((l, c)));
        assert_eq!(seen[0], (0, [0, 0, 0]));
        assert_eq!(seen[1], (1, [0, 0, 1]));
        assert_eq!(seen[2], (2, [0, 1, 0]));
        assert_eq!(seen[7], (7, [1, 1, 1]));
        assert_eq!(seen.len(), 8);
    }
}

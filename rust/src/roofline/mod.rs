//! Roofline machine characterization (§III-B, Figs 1 & 4).
//!
//! An ERT-like pair of microkernels measures the two ceilings:
//! * sustainable DRAM bandwidth — a STREAM-triad sweep over arrays far
//!   larger than LLC;
//! * peak f32 FLOP rate — independent FMA chains over register-resident
//!   lanes (auto-vectorized, matching how the dual-quant code reaches SIMD).
//!
//! The module also derives the dual-quant operational-intensity bounds
//! (conservative = arithmetic only; lenient = + rounds/compares/casts, per
//! the paper) and classifies measured runs against the model.

use crate::util::timer::Timer;

/// Machine ceilings measured by the microkernels.
#[derive(Clone, Copy, Debug)]
pub struct Ceilings {
    pub dram_gb_s: f64,
    pub peak_gflop_s: f64,
}

/// Host description (Table I analog).
#[derive(Clone, Debug, Default)]
pub struct HostInfo {
    pub model: String,
    pub cores: usize,
    pub cache_kb: usize,
    pub has_avx2: bool,
    pub has_avx512: bool,
}

/// Read /proc/cpuinfo (Linux) — best-effort.
pub fn host_info() -> HostInfo {
    let mut info = HostInfo {
        cores: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        ..Default::default()
    };
    if let Ok(txt) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in txt.lines() {
            if info.model.is_empty() && line.starts_with("model name") {
                info.model = line.split(':').nth(1).unwrap_or("").trim().to_string();
            } else if line.starts_with("cache size") {
                if let Some(kb) =
                    line.split(':').nth(1).and_then(|s| s.trim().split(' ').next())
                {
                    info.cache_kb = kb.parse().unwrap_or(0);
                }
            } else if line.starts_with("flags") {
                info.has_avx2 |= line.contains(" avx2");
                info.has_avx512 |= line.contains(" avx512f");
            }
        }
    }
    info
}

/// STREAM-triad sustainable bandwidth. `n` elements per array (default
/// sizing via [`measure_ceilings`] uses 32 Mi = 3x128 MiB footprint).
pub fn stream_triad_gb_s(n: usize, reps: usize) -> f64 {
    let mut a = vec![0.0f32; n];
    let b = vec![1.5f32; n];
    let c = vec![2.5f32; n];
    let s = 3.0f32;
    // warm
    triad(&mut a, &b, &c, s);
    let t = Timer::start();
    for _ in 0..reps {
        triad(&mut a, &b, &c, s);
    }
    let secs = t.elapsed_s();
    // 3 streams x 4 bytes (2 reads + 1 write) per element per rep
    (n as f64 * 12.0 * reps as f64) / secs / 1e9
}

#[inline(never)]
fn triad(a: &mut [f32], b: &[f32], c: &[f32], s: f32) {
    for i in 0..a.len() {
        a[i] = b[i] + s * c[i];
    }
}

/// Peak f32 GFLOP/s: independent FMA chains over a flat lane array.
///
/// The flat `[f32; 128]` with a single vectorizable loop is deliberate:
/// LLVM promotes it to 8 zmm (or 16 ymm) accumulators held in registers
/// across the unrolled outer iterations, giving true FMA-throughput
/// numbers; nested per-chain arrays spill to the stack and measure L1
/// latency instead (30x low).
pub fn peak_gflops(ms_budget: u64) -> f64 {
    const N: usize = 128; // 8 zmm registers worth of f32 lanes
    let mut acc = [1.000_1f32; N];
    let m = std::hint::black_box(1.000_000_1f32);
    let a = std::hint::black_box(1e-7f32);
    let mut iters = 0u64;
    let t = Timer::start();
    loop {
        for _ in 0..8192 {
            for x in acc.iter_mut() {
                *x = x.mul_add(m, a);
            }
        }
        iters += 8192;
        if t.elapsed().as_millis() as u64 >= ms_budget {
            break;
        }
    }
    let secs = t.elapsed_s();
    // keep the accumulators observable so the loop is not eliminated
    let sink: f32 = acc.iter().sum();
    std::hint::black_box(sink);
    // 2 flops (mul+add) per lane per iter
    (iters as f64 * N as f64 * 2.0) / secs / 1e9
}

/// Measure both ceilings (seconds-scale; used by `vecsz roofline`).
pub fn measure_ceilings(quick: bool) -> Ceilings {
    let (n, reps, ms) = if quick { (1 << 22, 3, 150) } else { (1 << 25, 5, 800) };
    Ceilings { dram_gb_s: stream_triad_gb_s(n, reps), peak_gflop_s: peak_gflops(ms) }
}

/// Dual-quant per-element operation counts (§III-B bounds).
#[derive(Clone, Copy, Debug)]
pub struct OiModel {
    pub flops_conservative: f64,
    pub flops_lenient: f64,
    pub bytes: f64,
}

/// Per-element counts for the dual-quant kernel of dimensionality `ndim`.
///
/// conservative: arithmetic only — prequant mul, Lorenzo adds/subs, delta.
/// lenient:      + round, |.| compare, cast, select.
/// bytes: f32 read + u16 code write + f32 outlier-lane write = 10 B.
pub fn oi_model(ndim: usize) -> OiModel {
    let lorenzo_ops = match ndim {
        1 => 1.0,  // delta = dq - W
        2 => 3.0,  // W + N - NW, delta
        _ => 7.0,  // 3 adds + 3 subs + 1 add, delta
    };
    let conservative = 1.0 + lorenzo_ops + 1.0; // prequant mul + lorenzo + code add
    let lenient = conservative + 4.0; // round, cmp, cast, select
    OiModel { flops_conservative: conservative, flops_lenient: lenient, bytes: 10.0 }
}

impl OiModel {
    pub fn oi_conservative(&self) -> f64 {
        self.flops_conservative / self.bytes
    }
    pub fn oi_lenient(&self) -> f64 {
        self.flops_lenient / self.bytes
    }
}

/// Roofline evaluation of a measured kernel run.
#[derive(Clone, Copy, Debug)]
pub struct RooflinePoint {
    pub oi: f64,
    pub gflop_s: f64,
    /// Attainable at this OI = min(peak, OI * DRAM BW).
    pub attainable_gflop_s: f64,
    /// Fraction of attainable reached (the paper's "percentage of peak
    /// DRAM bandwidth" when memory-bound).
    pub fraction_of_roof: f64,
    pub memory_bound: bool,
}

/// Place a measured run on the roofline.
pub fn evaluate(ceilings: Ceilings, oi: f64, gflop_s: f64) -> RooflinePoint {
    let mem_roof = oi * ceilings.dram_gb_s;
    let attainable = mem_roof.min(ceilings.peak_gflop_s);
    RooflinePoint {
        oi,
        gflop_s,
        attainable_gflop_s: attainable,
        fraction_of_roof: gflop_s / attainable.max(f64::MIN_POSITIVE),
        memory_bound: mem_roof < ceilings.peak_gflop_s,
    }
}

/// GFLOP/s of a dual-quant run given elements processed and seconds
/// (flops model `lenient?`).
pub fn dualquant_gflops(ndim: usize, elements: usize, secs: f64, lenient: bool) -> f64 {
    let m = oi_model(ndim);
    let f = if lenient { m.flops_lenient } else { m.flops_conservative };
    elements as f64 * f / secs.max(f64::MIN_POSITIVE) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oi_increases_with_dim_and_is_memory_bound_at_hpc_ratios() {
        let o1 = oi_model(1);
        let o2 = oi_model(2);
        let o3 = oi_model(3);
        assert!(o1.oi_conservative() < o2.oi_conservative());
        assert!(o2.oi_conservative() < o3.oi_conservative());
        assert!(o1.oi_lenient() > o1.oi_conservative());
        // typical server: 100 GB/s DRAM, 1 TFLOP f32 -> knee at OI 10;
        // all dual-quant OIs are far below the knee (paper: memory-bound)
        for o in [o1, o2, o3] {
            assert!(o.oi_lenient() < 2.0);
        }
    }

    #[test]
    fn evaluate_classifies_memory_bound() {
        let c = Ceilings { dram_gb_s: 100.0, peak_gflop_s: 1000.0 };
        let p = evaluate(c, 0.5, 25.0);
        assert!(p.memory_bound);
        assert!((p.attainable_gflop_s - 50.0).abs() < 1e-9);
        assert!((p.fraction_of_roof - 0.5).abs() < 1e-9);
        let p2 = evaluate(c, 100.0, 800.0);
        assert!(!p2.memory_bound);
        assert_eq!(p2.attainable_gflop_s, 1000.0);
    }

    #[test]
    fn microkernels_produce_positive_rates() {
        // tiny sizes: smoke only (CI-friendly)
        let bw = stream_triad_gb_s(1 << 16, 2);
        assert!(bw > 0.1, "triad {bw} GB/s");
        let gf = peak_gflops(30);
        assert!(gf > 0.1, "fma {gf} GFLOP/s");
    }

    #[test]
    fn host_info_smoke() {
        let h = host_info();
        assert!(h.cores >= 1);
    }

    #[test]
    fn gflops_math() {
        // 1e9 elements in 1 s at 3 flops/elem = 3 GFLOP/s
        let g = dualquant_gflops(1, 1_000_000_000, 1.0, false);
        assert!((g - 3.0).abs() < 1e-9);
    }
}

//! Deterministic fault-injection tests (ISSUE-7): crash/torn-write
//! recovery and deadline cancellation, driven by the `failpoint` module.
//!
//! The failpoint registry is process-global, so every test here serializes
//! on one mutex and clears the configuration before returning — these
//! tests must NOT share a binary with unrelated parallel tests.
//!
//! * kill-resume: a `vsz stream compress` subprocess is killed mid-run by
//!   a `VECSZ_FAILPOINTS` panic/torn-write (site and hit configurable via
//!   `VECSZ_FAILPOINTS_MATRIX`, the CI matrix hook); `--resume` must then
//!   complete the container **byte-identically** to an uninterrupted run.
//! * torn-write salvage: a torn frame write leaves a half-written frame;
//!   `salvage()` must recover every intact chunk bit-exactly and report
//!   the hole.
//! * deadline cancellation: a failpoint-delayed chunk job makes a request
//!   overrun its deadline; the reply must be `busy`, sibling jobs must
//!   report cancellation, and the admission gauge must return to zero.
//! * truncation sweep: every prefix of a valid container either salvages
//!   cleanly or errors — never panics.

// Salvage verification reads chunks through the legacy (deprecated)
// StreamDecompressor wrappers on purpose: they are the pinned v3 API.
#![allow(deprecated)]

use std::io::Cursor;
use std::process::Command;
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use vecsz::compressor::{Config, EbMode};
use vecsz::data::Field;
use vecsz::failpoint;
use vecsz::server::{is_busy, Client, ServeConfig, Server};
use vecsz::stream::{self, StreamDecompressor};
use vecsz::util::prng::Pcg32;

/// Failpoints are process-global state: serialize every test in this
/// binary (and recover from a poisoned lock — a failed test must not
/// cascade).
static FP_LOCK: Mutex<()> = Mutex::new(());

fn fp_lock() -> MutexGuard<'static, ()> {
    FP_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn smooth_field(name: &str, rows: usize, cols: usize, seed: u64) -> Field {
    let dims = vecsz::blocks::Dims::d2(rows, cols);
    let mut rng = Pcg32::seeded(seed);
    let mut x = 0.0f32;
    let data: Vec<f32> = (0..dims.len())
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    Field::new(name, dims, data)
}

fn f32_le_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn serial_cfg(eb: f64) -> Config {
    Config { eb: EbMode::Abs(eb), threads: 1, ..Config::default() }
}

fn start_server(cfg: ServeConfig) -> (String, std::thread::JoinHandle<()>) {
    let srv = Server::bind("127.0.0.1:0", cfg).expect("bind ephemeral port");
    let addr = srv.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || srv.run().expect("server run"));
    (addr, h)
}

/// Scratch directory for subprocess artifacts, unique per test name so
/// parallel `cargo test` binaries cannot collide.
fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("vsz_fault_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

#[test]
fn killed_compress_resumes_to_byte_identical_container() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let dir = scratch("kill_resume");
    let field = smooth_field("kr", 64, 48, 0xAB);
    let input = dir.join("kr.f32");
    std::fs::write(&input, f32_le_bytes(&field.data)).unwrap();
    let out = dir.join("kr.vsz");
    let reference_out = dir.join("kr_ref.vsz");
    let _ = std::fs::remove_file(&out);

    // the CI matrix can swap in any crash point; default: panic (simulated
    // kill) while encoding the third chunk of eight
    let fp = std::env::var("VECSZ_FAILPOINTS_MATRIX")
        .unwrap_or_else(|_| "chunk_encode:3=panic".into());
    let base_args = |out: &std::path::Path| {
        vec![
            "stream".to_string(),
            "compress".to_string(),
            "--input".into(),
            input.to_str().unwrap().into(),
            "--dims".into(),
            "64x48".into(),
            "--out".into(),
            out.to_str().unwrap().to_string(),
            "--eb".into(),
            "1e-3".into(),
            "--chunk-rows".into(),
            "8".into(),
        ]
    };

    // 1. the run dies at the injected fault, leaving a partial container
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(base_args(&out))
        .env("VECSZ_FAILPOINTS", &fp)
        .status()
        .expect("spawn vsz");
    assert!(!status.success(), "failpoint '{fp}' should have aborted the compress");

    // 2. --resume (no failpoints) completes the container
    let mut resume_args = base_args(&out);
    resume_args.push("--resume".into());
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(&resume_args)
        .env_remove("VECSZ_FAILPOINTS")
        .status()
        .expect("spawn vsz resume");
    assert!(status.success(), "resume must succeed once the fault is gone");

    // 3. an uninterrupted run of the same CLI is the byte-level reference
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(base_args(&reference_out))
        .env_remove("VECSZ_FAILPOINTS")
        .status()
        .expect("spawn vsz reference");
    assert!(status.success());
    let resumed = std::fs::read(&out).unwrap();
    let reference = std::fs::read(&reference_out).unwrap();
    assert_eq!(
        resumed, reference,
        "resumed container must be byte-identical to an uninterrupted run"
    );

    // and it decodes: the round-trip respects the bound
    let mut dec = StreamDecompressor::new(Cursor::new(&resumed[..])).unwrap();
    let mut decoded = Vec::new();
    while let Some(c) = dec.next_chunk().unwrap() {
        decoded.extend_from_slice(&c.data);
    }
    assert_eq!(decoded.len(), field.data.len());
    for (a, b) in decoded.iter().zip(field.data.iter()) {
        assert!((*a as f64 - *b as f64).abs() <= 1.0001e-3, "resumed container breaks the bound");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_frame_write_salvages_the_valid_prefix() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let field = smooth_field("torn", 48, 32, 0xC0); // span 8 -> 6 chunks
    let cfg = serial_cfg(1e-3);
    let (intact, _) = stream::compress_chunked(&field, &cfg, 8).unwrap();

    // tear the third frame write: chunks 0 and 1 land whole, chunk 2 is
    // half-written, nothing after it exists
    let dir = scratch("torn");
    let path = dir.join("torn.vsz");
    failpoint::set_config_for_tests("frame_write:3=torn");
    let err = stream::compress_stream_with(
        Cursor::new(f32_le_bytes(&field.data)),
        std::io::BufWriter::new(std::fs::File::create(&path).unwrap()),
        field.dims,
        &cfg,
        8,
        stream::StreamOptions::default(),
    )
    .unwrap_err();
    failpoint::set_config_for_tests("");
    assert!(err.to_string().contains("torn"), "unexpected error: {err}");

    let mut dec = StreamDecompressor::new(std::fs::File::open(&path).unwrap()).unwrap();
    let (chunks, report) = dec.salvage().expect("salvage walks the partial file");
    assert_eq!(report.total_chunks, 6);
    assert_eq!(report.recovered, vec![0, 1], "the two whole frames recover");
    assert!(!report.is_complete());
    assert!(!report.footer_ok && !report.trailer_found);
    assert_eq!(report.holes.len(), 1, "holes: {:?}", report.holes);
    assert_eq!(report.holes[0].chunk_index, 2);
    assert_eq!(report.holes[0].n_chunks, 4);
    assert_eq!(report.holes[0].rows, 16..48);
    let json = report.to_json();
    assert!(json.contains("\"complete\":false"), "{json}");

    // recovered chunks are bit-exact against the intact container's decode
    let mut reference = StreamDecompressor::new(Cursor::new(&intact[..])).unwrap();
    for c in &chunks {
        let r = reference.decode_chunk(c.index as usize).unwrap();
        assert_eq!(c.lead_offset, r.lead_offset);
        assert_eq!(c.data.len(), r.data.len());
        for (a, b) in c.data.iter().zip(r.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "chunk {} differs", c.index);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deadline_expiry_cancels_chunk_jobs_and_recovers() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    // one worker + a 1000 ms stall on the first chunk encode: the three
    // sibling jobs sit queued past the 150 ms deadline and must come back
    // Cancelled when the executor dequeues them
    let (addr, server) = start_server(ServeConfig {
        threads: 1,
        request_timeout_ms: 150,
        ..ServeConfig::default()
    });
    let field = smooth_field("dl", 64, 48, 0x11); // span 16 -> 4 chunks
    let cfg = serial_cfg(1e-3);
    let (reference, _) = stream::compress_chunked(&field, &cfg, 16).unwrap();

    failpoint::set_config_for_tests("chunk_encode:1=delay(1000)");
    let mut c = Client::connect(&addr).expect("connect");
    let t0 = Instant::now();
    let err = c.compress("dl", "64x48", 1e-3, 16, &field.data).unwrap_err();
    failpoint::set_config_for_tests("");
    let waited = t0.elapsed();
    assert!(is_busy(&err), "deadline reply must be busy-classified, got: {err}");
    let msg = err.to_string();
    assert!(msg.contains("deadline"), "reply must name the deadline: {msg}");
    assert!(msg.contains("cancelled"), "sibling jobs must report cancellation: {msg}");
    assert!(waited >= Duration::from_millis(150), "cannot reply before the deadline");

    // same connection, fault gone: the request completes bit-identically
    let (bytes, _) = c.compress("dl", "64x48", 1e-3, 16, &field.data).expect("recovers");
    assert_eq!(bytes, reference, "post-deadline compress must be byte-identical");

    // the timed-out request must not leak admission budget
    let stats = c.stats().expect("stats");
    let j = vecsz::util::json::parse(&stats).unwrap();
    assert_eq!(
        j.get("inflight_bytes").and_then(|v| v.as_f64()),
        Some(0.0),
        "admission gauge must return to zero: {stats}"
    );
    assert!(stats.contains("\"request_timeout_ms\":150"), "{stats}");

    c.shutdown().expect("shutdown");
    drop(c);
    server.join().expect("server exits");
}

#[test]
fn injected_response_write_error_fails_connection_not_server() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let (addr, server) = start_server(ServeConfig { threads: 1, ..ServeConfig::default() });
    // the very first response frame write errors: that connection dies,
    // the server must keep accepting
    failpoint::set_config_for_tests("serve_frame_write:1=err");
    let mut c = Client::connect(&addr).expect("connect");
    let err = c.stats().unwrap_err();
    failpoint::set_config_for_tests("");
    assert!(
        err.to_string().contains("closed the connection") || matches!(err, vecsz::VszError::Io(_)),
        "client should observe the dropped connection: {err}"
    );
    let mut c2 = Client::connect(&addr).expect("server still accepts");
    assert!(c2.stats().is_ok(), "a fresh connection works");
    c2.shutdown().expect("shutdown");
    drop(c2);
    server.join().expect("server exits");
}

#[test]
fn every_prefix_of_a_container_salvages_or_errors_never_panics() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let field = smooth_field("sweep", 32, 16, 0x77); // span 8 -> 4 chunks
    let cfg = serial_cfg(1e-3);
    let (container, _) = stream::compress_chunked(&field, &cfg, 8).unwrap();

    let mut reference = StreamDecompressor::new(Cursor::new(&container[..])).unwrap();
    let n_chunks = reference.load_index().unwrap().n_chunks();
    let ref_chunks: Vec<Vec<f32>> =
        (0..n_chunks).map(|k| reference.decode_chunk(k).unwrap().data).collect();

    for cut in 0..=container.len() {
        let prefix = container[..cut].to_vec();
        // a cut inside the stream header cannot construct a decoder at
        // all — a clean error, which is the contract
        let Ok(mut dec) = StreamDecompressor::new(Cursor::new(prefix)) else { continue };
        match dec.salvage() {
            Ok((chunks, report)) => {
                assert_eq!(
                    chunks.len(),
                    report.recovered.len(),
                    "cut {cut}: report must count exactly the returned chunks"
                );
                assert!(report.rows_recovered <= report.total_rows, "cut {cut}");
                for c in &chunks {
                    // anything salvage hands back is bit-exact — a CRC-failed
                    // chunk must be quarantined, never returned
                    let r = &ref_chunks[c.index as usize];
                    assert_eq!(c.data.len(), r.len(), "cut {cut} chunk {}", c.index);
                    for (a, b) in c.data.iter().zip(r.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "cut {cut} chunk {}", c.index);
                    }
                }
                if cut == container.len() {
                    assert!(report.is_complete(), "the untruncated container is complete");
                }
            }
            Err(_) => {} // clean errors are acceptable; panics are not
        }
    }
}

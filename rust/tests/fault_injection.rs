//! Deterministic fault-injection tests (ISSUE-7): crash/torn-write
//! recovery and deadline cancellation, driven by the `failpoint` module.
//!
//! The failpoint registry is process-global, so every test here serializes
//! on one mutex and clears the configuration before returning — these
//! tests must NOT share a binary with unrelated parallel tests.
//!
//! * kill-resume: a `vsz stream compress` subprocess is killed mid-run by
//!   a `VECSZ_FAILPOINTS` panic/torn-write (site and hit configurable via
//!   `VECSZ_FAILPOINTS_MATRIX`, the CI matrix hook); `--resume` must then
//!   complete the container **byte-identically** to an uninterrupted run.
//! * torn-write salvage: a torn frame write leaves a half-written frame;
//!   `salvage()` must recover every intact chunk bit-exactly and report
//!   the hole.
//! * deadline cancellation: a failpoint-delayed chunk job makes a request
//!   overrun its deadline; the reply must be `busy`, sibling jobs must
//!   report cancellation, and the admission gauge must return to zero.
//! * truncation sweep: every prefix of a valid container either salvages
//!   cleanly or errors — never panics.

// Salvage verification reads chunks through the legacy (deprecated)
// StreamDecompressor wrappers on purpose: they are the pinned v3 API.
#![allow(deprecated)]

use std::io::Cursor;
use std::process::Command;
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use vecsz::compressor::{Config, EbMode};
use vecsz::data::Field;
use vecsz::failpoint;
use vecsz::huffman;
use vecsz::server::{is_busy, Client, ServeConfig, Server};
use vecsz::stream::{self, Dataset, Region, StreamDecompressor};
use vecsz::util::prng::Pcg32;

/// Failpoints are process-global state: serialize every test in this
/// binary (and recover from a poisoned lock — a failed test must not
/// cascade).
static FP_LOCK: Mutex<()> = Mutex::new(());

fn fp_lock() -> MutexGuard<'static, ()> {
    FP_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn smooth_field(name: &str, rows: usize, cols: usize, seed: u64) -> Field {
    let dims = vecsz::blocks::Dims::d2(rows, cols);
    let mut rng = Pcg32::seeded(seed);
    let mut x = 0.0f32;
    let data: Vec<f32> = (0..dims.len())
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    Field::new(name, dims, data)
}

fn f32_le_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn serial_cfg(eb: f64) -> Config {
    Config { eb: EbMode::Abs(eb), threads: 1, ..Config::default() }
}

fn start_server(cfg: ServeConfig) -> (String, std::thread::JoinHandle<()>) {
    let srv = Server::bind("127.0.0.1:0", cfg).expect("bind ephemeral port");
    let addr = srv.local_addr().unwrap().to_string();
    let h = std::thread::spawn(move || srv.run().expect("server run"));
    (addr, h)
}

/// Scratch directory for subprocess artifacts, unique per test name so
/// parallel `cargo test` binaries cannot collide.
fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("vsz_fault_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

#[test]
fn killed_compress_resumes_to_byte_identical_container() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let dir = scratch("kill_resume");
    let field = smooth_field("kr", 64, 48, 0xAB);
    let input = dir.join("kr.f32");
    std::fs::write(&input, f32_le_bytes(&field.data)).unwrap();
    let out = dir.join("kr.vsz");
    let reference_out = dir.join("kr_ref.vsz");
    let _ = std::fs::remove_file(&out);

    // the CI matrix can swap in any crash point; default: panic (simulated
    // kill) while encoding the third chunk of eight. Decode-side sites
    // (e.g. `huffman_decode`, hit by the HUF3 gap-array segment loop)
    // cannot abort a compress — those entries instead abort a
    // `vsz stream decompress` of a cleanly-written container, which must
    // then succeed once the fault is lifted.
    let fp = std::env::var("VECSZ_FAILPOINTS_MATRIX")
        .unwrap_or_else(|_| "chunk_encode:3=panic".into());
    let decode_site = fp.starts_with("huffman_decode") || fp.starts_with("chunk_decode");
    let base_args = |out: &std::path::Path| {
        vec![
            "stream".to_string(),
            "compress".to_string(),
            "--input".into(),
            input.to_str().unwrap().into(),
            "--dims".into(),
            "64x48".into(),
            "--out".into(),
            out.to_str().unwrap().to_string(),
            "--eb".into(),
            "1e-3".into(),
            "--chunk-rows".into(),
            "8".into(),
        ]
    };

    if decode_site {
        // decode-site leg: compress cleanly, prove the failpoint aborts a
        // stream decompress, then that the same container decodes once the
        // fault is gone and the round-trip respects the bound
        let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
            .args(base_args(&out))
            .env_remove("VECSZ_FAILPOINTS")
            .status()
            .expect("spawn vsz");
        assert!(status.success(), "clean compress must succeed for a decode-site entry");
        let raw = dir.join("kr.out.f32");
        let dec_args = [
            "stream",
            "decompress",
            "--input",
            out.to_str().unwrap(),
            "--out",
            raw.to_str().unwrap(),
        ];
        let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
            .args(dec_args)
            .env("VECSZ_FAILPOINTS", &fp)
            .status()
            .expect("spawn vsz decompress");
        assert!(!status.success(), "failpoint '{fp}' should have aborted the decompress");
        let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
            .args(dec_args)
            .env_remove("VECSZ_FAILPOINTS")
            .status()
            .expect("spawn vsz decompress retry");
        assert!(status.success(), "decompress must succeed once the fault is gone");
        let decoded = std::fs::read(&raw).unwrap();
        assert_eq!(decoded.len(), field.data.len() * 4);
        for (chunk, b) in decoded.chunks_exact(4).zip(field.data.iter()) {
            let a = f32::from_le_bytes(chunk.try_into().unwrap());
            assert!((a as f64 - *b as f64).abs() <= 1.0001e-3, "decode breaks the bound");
        }
        let _ = std::fs::remove_dir_all(&dir);
        return;
    }

    // 1. the run dies at the injected fault, leaving a partial container
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(base_args(&out))
        .env("VECSZ_FAILPOINTS", &fp)
        .status()
        .expect("spawn vsz");
    assert!(!status.success(), "failpoint '{fp}' should have aborted the compress");

    // 2. --resume (no failpoints) completes the container
    let mut resume_args = base_args(&out);
    resume_args.push("--resume".into());
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(&resume_args)
        .env_remove("VECSZ_FAILPOINTS")
        .status()
        .expect("spawn vsz resume");
    assert!(status.success(), "resume must succeed once the fault is gone");

    // 3. an uninterrupted run of the same CLI is the byte-level reference
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(base_args(&reference_out))
        .env_remove("VECSZ_FAILPOINTS")
        .status()
        .expect("spawn vsz reference");
    assert!(status.success());
    let resumed = std::fs::read(&out).unwrap();
    let reference = std::fs::read(&reference_out).unwrap();
    assert_eq!(
        resumed, reference,
        "resumed container must be byte-identical to an uninterrupted run"
    );

    // and it decodes: the round-trip respects the bound
    let mut dec = StreamDecompressor::new(Cursor::new(&resumed[..])).unwrap();
    let mut decoded = Vec::new();
    while let Some(c) = dec.next_chunk().unwrap() {
        decoded.extend_from_slice(&c.data);
    }
    assert_eq!(decoded.len(), field.data.len());
    for (a, b) in decoded.iter().zip(field.data.iter()) {
        assert!((*a as f64 - *b as f64).abs() <= 1.0001e-3, "resumed container breaks the bound");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_frame_write_salvages_the_valid_prefix() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let field = smooth_field("torn", 48, 32, 0xC0); // span 8 -> 6 chunks
    let cfg = serial_cfg(1e-3);
    let (intact, _) = stream::compress_chunked(&field, &cfg, 8).unwrap();

    // tear the third frame write: chunks 0 and 1 land whole, chunk 2 is
    // half-written, nothing after it exists
    let dir = scratch("torn");
    let path = dir.join("torn.vsz");
    failpoint::set_config_for_tests("frame_write:3=torn");
    let err = stream::compress_stream_with(
        Cursor::new(f32_le_bytes(&field.data)),
        std::io::BufWriter::new(std::fs::File::create(&path).unwrap()),
        field.dims,
        &cfg,
        8,
        stream::StreamOptions::default(),
    )
    .unwrap_err();
    failpoint::set_config_for_tests("");
    assert!(err.to_string().contains("torn"), "unexpected error: {err}");

    let mut dec = StreamDecompressor::new(std::fs::File::open(&path).unwrap()).unwrap();
    let (chunks, report) = dec.salvage().expect("salvage walks the partial file");
    assert_eq!(report.total_chunks, 6);
    assert_eq!(report.recovered, vec![0, 1], "the two whole frames recover");
    assert!(!report.is_complete());
    assert!(!report.footer_ok && !report.trailer_found);
    assert_eq!(report.holes.len(), 1, "holes: {:?}", report.holes);
    assert_eq!(report.holes[0].chunk_index, 2);
    assert_eq!(report.holes[0].n_chunks, 4);
    assert_eq!(report.holes[0].rows, 16..48);
    let json = report.to_json();
    assert!(json.contains("\"complete\":false"), "{json}");

    // recovered chunks are bit-exact against the intact container's decode
    let mut reference = StreamDecompressor::new(Cursor::new(&intact[..])).unwrap();
    for c in &chunks {
        let r = reference.decode_chunk(c.index as usize).unwrap();
        assert_eq!(c.lead_offset, r.lead_offset);
        assert_eq!(c.data.len(), r.data.len());
        for (a, b) in c.data.iter().zip(r.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "chunk {} differs", c.index);
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deadline_expiry_cancels_chunk_jobs_and_recovers() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    // one worker + a 1000 ms stall on the first chunk encode: the three
    // sibling jobs sit queued past the 150 ms deadline and must come back
    // Cancelled when the executor dequeues them
    let (addr, server) = start_server(ServeConfig {
        threads: 1,
        request_timeout_ms: 150,
        ..ServeConfig::default()
    });
    let field = smooth_field("dl", 64, 48, 0x11); // span 16 -> 4 chunks
    let cfg = serial_cfg(1e-3);
    let (reference, _) = stream::compress_chunked(&field, &cfg, 16).unwrap();

    failpoint::set_config_for_tests("chunk_encode:1=delay(1000)");
    let mut c = Client::connect(&addr).expect("connect");
    let t0 = Instant::now();
    let err = c.compress("dl", "64x48", 1e-3, 16, &field.data).unwrap_err();
    failpoint::set_config_for_tests("");
    let waited = t0.elapsed();
    assert!(is_busy(&err), "deadline reply must be busy-classified, got: {err}");
    let msg = err.to_string();
    assert!(msg.contains("deadline"), "reply must name the deadline: {msg}");
    assert!(msg.contains("cancelled"), "sibling jobs must report cancellation: {msg}");
    assert!(waited >= Duration::from_millis(150), "cannot reply before the deadline");

    // same connection, fault gone: the request completes bit-identically
    let (bytes, _) = c.compress("dl", "64x48", 1e-3, 16, &field.data).expect("recovers");
    assert_eq!(bytes, reference, "post-deadline compress must be byte-identical");

    // the timed-out request must not leak admission budget
    let stats = c.stats().expect("stats");
    let j = vecsz::util::json::parse(&stats).unwrap();
    assert_eq!(
        j.get("inflight_bytes").and_then(|v| v.as_f64()),
        Some(0.0),
        "admission gauge must return to zero: {stats}"
    );
    assert!(stats.contains("\"request_timeout_ms\":150"), "{stats}");

    c.shutdown().expect("shutdown");
    drop(c);
    server.join().expect("server exits");
}

#[test]
fn injected_response_write_error_fails_connection_not_server() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let (addr, server) = start_server(ServeConfig { threads: 1, ..ServeConfig::default() });
    // the very first response frame write errors: that connection dies,
    // the server must keep accepting
    failpoint::set_config_for_tests("serve_frame_write:1=err");
    let mut c = Client::connect(&addr).expect("connect");
    let err = c.stats().unwrap_err();
    failpoint::set_config_for_tests("");
    assert!(
        err.to_string().contains("closed the connection") || matches!(err, vecsz::VszError::Io(_)),
        "client should observe the dropped connection: {err}"
    );
    let mut c2 = Client::connect(&addr).expect("server still accepts");
    assert!(c2.stats().is_ok(), "a fresh connection works");
    c2.shutdown().expect("shutdown");
    drop(c2);
    server.join().expect("server exits");
}

#[test]
fn every_prefix_of_a_container_salvages_or_errors_never_panics() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let field = smooth_field("sweep", 32, 16, 0x77); // span 8 -> 4 chunks
    let cfg = serial_cfg(1e-3);
    let (container, _) = stream::compress_chunked(&field, &cfg, 8).unwrap();

    let mut reference = StreamDecompressor::new(Cursor::new(&container[..])).unwrap();
    let n_chunks = reference.load_index().unwrap().n_chunks();
    let ref_chunks: Vec<Vec<f32>> =
        (0..n_chunks).map(|k| reference.decode_chunk(k).unwrap().data).collect();

    for cut in 0..=container.len() {
        let prefix = container[..cut].to_vec();
        // a cut inside the stream header cannot construct a decoder at
        // all — a clean error, which is the contract
        let Ok(mut dec) = StreamDecompressor::new(Cursor::new(prefix)) else { continue };
        match dec.salvage() {
            Ok((chunks, report)) => {
                assert_eq!(
                    chunks.len(),
                    report.recovered.len(),
                    "cut {cut}: report must count exactly the returned chunks"
                );
                assert!(report.rows_recovered <= report.total_rows, "cut {cut}");
                for c in &chunks {
                    // anything salvage hands back is bit-exact — a CRC-failed
                    // chunk must be quarantined, never returned
                    let r = &ref_chunks[c.index as usize];
                    assert_eq!(c.data.len(), r.len(), "cut {cut} chunk {}", c.index);
                    for (a, b) in c.data.iter().zip(r.iter()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "cut {cut} chunk {}", c.index);
                    }
                }
                if cut == container.len() {
                    assert!(report.is_complete(), "the untruncated container is complete");
                }
            }
            Err(_) => {} // clean errors are acceptable; panics are not
        }
    }
}

#[test]
fn failed_cold_read_leaves_no_resident_slab_and_retries_clean() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let field = smooth_field("cold", 48, 32, 0x3A); // span 8 -> 6 chunks
    let cfg = serial_cfg(1e-3);
    let (container, _) = stream::compress_chunked(&field, &cfg, 8).unwrap();
    let reference = stream::decompress_chunked(&container, 1).unwrap();

    let ds = Dataset::open(Cursor::new(&container)).unwrap();
    failpoint::set_config_for_tests("chunk_decode:1=err");
    let err = ds.read(Region::Chunk(0)).unwrap_err();
    assert!(err.to_string().contains("failpoint"), "unexpected error: {err}");
    // the failed decode must not become resident, in the map or the gauge
    assert_eq!(ds.cache().resident_chunks(), 0, "failed decode left a resident slab");
    assert_eq!(ds.cache_stats().resident_bytes, 0);
    // with the fault gone the same handle recovers, bit-identically
    failpoint::set_config_for_tests("");
    assert_eq!(ds.read(Region::All).unwrap(), reference.data);
    assert!(ds.cache().resident_chunks() > 0);
    assert_eq!(ds.cache_stats().repaired_reads, 0, "no parity layer, nothing to repair");
}

#[test]
fn huffman_decode_failpoint_aborts_gap_array_segments_then_clears() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    // a stream long enough to carry a gap array: the HUF3 decoder hits the
    // `huffman_decode` site once per segment, pooled or serial
    let mut rng = Pcg32::seeded(0x9D);
    let syms: Vec<u16> = (0..huffman::CHUNK_SYMS + 999)
        .map(|_| if rng.next_f32() < 0.9 { 7 } else { rng.bounded(256) as u16 })
        .collect();
    let opts = huffman::EntropyOptions::default();
    let blob = huffman::compress_u16_framed(&syms, 256, None, &opts);
    let info = huffman::inspect_payload(&blob).unwrap();
    assert_eq!(info.framing, "huf3");
    assert!(info.segments > 1, "workload must exercise the gap-array split");

    failpoint::set_config_for_tests("huffman_decode:1=err");
    let err = huffman::decompress_u16_pooled(&blob, None).unwrap_err();
    assert!(err.to_string().contains("failpoint"), "unexpected error: {err}");
    failpoint::set_config_for_tests("");
    assert_eq!(huffman::decompress_u16_pooled(&blob, None).unwrap(), syms);
}

#[test]
fn corrupt_chunk_errors_every_single_flight_waiter_without_hanging() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let field = smooth_field("sf", 48, 32, 0x4B); // span 8 -> 6 chunks
    let cfg = serial_cfg(1e-3);
    let (container, _) = stream::compress_chunked(&field, &cfg, 8).unwrap();
    let mut dec = StreamDecompressor::new(Cursor::new(&container[..])).unwrap();
    let e0 = dec.load_index().unwrap().entries[0];

    // flip a payload byte of chunk 0's frame: a parity-less container
    // cannot rebuild it, so every reader must see the CRC failure
    let mut bad = container.clone();
    bad[(e0.offset + e0.frame_len * 3 / 4) as usize] ^= 0x5A;
    let ds = Dataset::open(Cursor::new(&bad)).unwrap();

    // two concurrent cold reads of the same chunk: one claims the decode,
    // the other waits on the claim. The claimer bails at frame parse, so
    // the waiter must be released by the ClaimGuard abandonment — an
    // error, not a hang.
    let barrier = std::sync::Barrier::new(2);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                s.spawn(|| {
                    barrier.wait();
                    ds.read(Region::Chunk(0))
                })
            })
            .collect();
        for h in handles {
            let res = h.join().expect("reader must not panic");
            assert!(res.is_err(), "a CRC-failed chunk must never decode");
        }
    });
    assert_eq!(ds.cache().resident_chunks(), 0);
    // undamaged chunks still serve through the same handle
    assert!(ds.read(Region::Chunk(3)).is_ok());
}

#[test]
fn transient_frame_read_error_heals_through_parity() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let field = smooth_field("fr", 48, 32, 0x5C); // span 8 -> 6 chunks
    let cfg = serial_cfg(1e-3);
    let opts = stream::StreamOptions::builder().parity(3).build();
    let (par, _) = stream::compress_chunked_with(&field, &cfg, 8, opts).unwrap();
    let reference = stream::decompress_chunked(&par, 1).unwrap();

    // an injected read error on one frame is indistinguishable from bit
    // rot to the Dataset — with a parity layer it rebuilds and serves
    let ds = Dataset::open(Cursor::new(&par)).unwrap();
    failpoint::set_config_for_tests("frame_read:1=err");
    let data = ds.read(Region::All).expect("parity absorbs a single read fault");
    failpoint::set_config_for_tests("");
    assert_eq!(data, reference.data);
    assert!(ds.cache_stats().repaired_reads >= 1);

    // without parity the same fault surfaces as an error
    let (plain, _) = stream::compress_chunked(&field, &cfg, 8).unwrap();
    let ds2 = Dataset::open(Cursor::new(&plain)).unwrap();
    failpoint::set_config_for_tests("frame_read:1=err");
    assert!(ds2.read(Region::All).is_err());
    failpoint::set_config_for_tests("");
    assert!(ds2.read(Region::All).is_ok());
}

#[test]
fn killed_parity_compress_resumes_to_byte_identical_container() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let dir = scratch("parity_resume");
    let field = smooth_field("pr", 64, 48, 0xD4);
    let input = dir.join("pr.f32");
    std::fs::write(&input, f32_le_bytes(&field.data)).unwrap();
    let out = dir.join("pr.vsz");
    let reference_out = dir.join("pr_ref.vsz");
    let _ = std::fs::remove_file(&out);

    let base_args = |out: &std::path::Path| {
        vec![
            "stream".to_string(),
            "compress".to_string(),
            "--input".into(),
            input.to_str().unwrap().into(),
            "--dims".into(),
            "64x48".into(),
            "--out".into(),
            out.to_str().unwrap().to_string(),
            "--eb".into(),
            "1e-3".into(),
            "--chunk-rows".into(),
            "8".into(),
            "--parity".into(),
            "4".into(),
        ]
    };

    // die on the first parity frame write: all data frames are on disk,
    // the parity layer is torn mid-frame
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(base_args(&out))
        .env("VECSZ_FAILPOINTS", "parity_write:1=torn")
        .status()
        .expect("spawn vsz");
    assert!(!status.success(), "torn parity write should abort the compress");

    let mut resume_args = base_args(&out);
    resume_args.push("--resume".into());
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(&resume_args)
        .env_remove("VECSZ_FAILPOINTS")
        .status()
        .expect("spawn vsz resume");
    assert!(status.success(), "resume must rebuild the parity layer");

    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args(base_args(&reference_out))
        .env_remove("VECSZ_FAILPOINTS")
        .status()
        .expect("spawn vsz reference");
    assert!(status.success());
    assert_eq!(
        std::fs::read(&out).unwrap(),
        std::fs::read(&reference_out).unwrap(),
        "resumed parity container must be byte-identical to an uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The CI fault-injection matrix entry point (ISSUE-9): compress with
/// parity, flip one byte in every data and parity frame in turn, and
/// prove `vsz stream repair` restores the container byte-identically
/// while reads heal transparently and a two-loss group fails cleanly.
#[test]
fn parity_cli_scrubs_repairs_and_serves_through_bit_rot() {
    let _g = fp_lock();
    failpoint::set_config_for_tests("");
    let dir = scratch("parity_e2e");
    let field = smooth_field("e2e", 96, 24, 0xE2);
    let input = dir.join("e2e.f32");
    std::fs::write(&input, f32_le_bytes(&field.data)).unwrap();
    let out = dir.join("e2e.vsz");

    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args([
            "stream",
            "compress",
            "--input",
            input.to_str().unwrap(),
            "--dims",
            "96x24",
            "--out",
            out.to_str().unwrap(),
            "--eb",
            "1e-3",
            "--chunk-rows",
            "16",
            "--parity",
            "4",
        ])
        .env_remove("VECSZ_FAILPOINTS")
        .status()
        .expect("spawn vsz compress");
    assert!(status.success());
    let reference = std::fs::read(&out).unwrap();
    let decoded = stream::decompress_chunked(&reference, 1).unwrap();

    let mut dec = StreamDecompressor::new(Cursor::new(&reference[..])).unwrap();
    let idx = dec.load_index().unwrap().clone();
    assert_eq!(idx.entries.len(), 6, "6 chunks -> groups of 4 + 2");
    let parity = idx.parity.as_ref().expect("parity footer");
    let mut frames: Vec<(u64, u64)> =
        idx.entries.iter().map(|e| (e.offset, e.frame_len)).collect();
    frames.extend(parity.entries.iter().map(|p| (p.offset, p.frame_len)));

    let scrub = |mode: &str| {
        Command::new(env!("CARGO_BIN_EXE_vsz"))
            .args(["stream", mode, "--input", out.to_str().unwrap()])
            .env_remove("VECSZ_FAILPOINTS")
            .status()
            .expect("spawn vsz scrub/repair")
    };

    // one flipped byte per frame, every frame in turn: scrub reports the
    // damage (nonzero exit, file untouched), repair restores byte-identity
    for &(offset, frame_len) in &frames {
        let mut damaged = reference.clone();
        damaged[(offset + frame_len / 2) as usize] ^= 0xA5;
        std::fs::write(&out, &damaged).unwrap();
        let status = scrub("scrub");
        assert!(!status.success(), "scrub must flag the damage at {offset}");
        assert_eq!(std::fs::read(&out).unwrap(), damaged, "scrub must not write");
        let status = scrub("repair");
        assert!(status.success(), "repair must heal a single loss at {offset}");
        assert_eq!(
            std::fs::read(&out).unwrap(),
            reference,
            "repair at {offset} is not byte-identical"
        );
    }

    // transparent read-path recovery: a damaged chunk frame decodes
    // bit-identically through Dataset, counting the repair
    let (offset, frame_len) = frames[2];
    let mut damaged = reference.clone();
    damaged[(offset + frame_len / 2) as usize] ^= 0xA5;
    let ds = Dataset::open(Cursor::new(&damaged)).unwrap();
    assert_eq!(ds.read(Region::All).unwrap(), decoded.data);
    assert!(ds.cache_stats().repaired_reads > 0);

    // the server keeps answering through the same bit rot
    let (addr, server) = start_server(vecsz::server::ServeConfig::default());
    let mut c = Client::connect(&addr).expect("connect");
    let (data, _) = c.decompress(&damaged).expect("serve decompresses damaged container");
    assert_eq!(data, decoded.data);
    let stats = c.stats().unwrap();
    let j = vecsz::util::json::parse(&stats).unwrap();
    let repaired = j
        .get("cache")
        .and_then(|c| c.get("repaired_reads"))
        .and_then(|v| v.as_usize())
        .expect("status must carry the repair gauge");
    assert!(repaired >= 1, "{stats}");

    // two losses in one parity group: repair refuses (nonzero exit, no
    // panic, file untouched) and reads fail cleanly server-side too
    let mut two_loss = reference.clone();
    for k in [0usize, 1] {
        let (offset, frame_len) = frames[k];
        two_loss[(offset + frame_len / 2) as usize] ^= 0xA5;
    }
    std::fs::write(&out, &two_loss).unwrap();
    let status = scrub("repair");
    assert!(!status.success(), "a 2-loss group is beyond single-XOR parity");
    assert!(status.code().is_some(), "must exit, not die on a signal/panic");
    assert_eq!(std::fs::read(&out).unwrap(), two_loss, "failed repair must not write");
    assert!(c.decompress(&two_loss).is_err(), "2 losses must error, not fabricate data");
    assert!(c.stats().is_ok(), "the connection survives the failed decompress");

    c.shutdown().expect("shutdown");
    drop(c);
    server.join().expect("server exits");

    // the repaired container round-trips through the plain CLI decoder
    std::fs::write(&out, &reference).unwrap();
    let raw_out = dir.join("e2e_rt.f32");
    let status = Command::new(env!("CARGO_BIN_EXE_vsz"))
        .args([
            "stream",
            "decompress",
            "--input",
            out.to_str().unwrap(),
            "--out",
            raw_out.to_str().unwrap(),
        ])
        .env_remove("VECSZ_FAILPOINTS")
        .status()
        .expect("spawn vsz decompress");
    assert!(status.success());
    assert_eq!(std::fs::read(&raw_out).unwrap(), f32_le_bytes(&decoded.data));
    let _ = std::fs::remove_dir_all(&dir);
}

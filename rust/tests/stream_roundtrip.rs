//! Acceptance tests for the chunked streaming engine over the public API:
//! a field streamed through `StreamCompressor` in >= 4 chunks decompresses
//! within the error bound, chunk-parallel decode is byte-identical to
//! serial decode, and corrupted/truncated containers are rejected with an
//! error (never a panic).

use vecsz::blocks::Dims;
use vecsz::compressor::{decompress, Config, EbMode};
use vecsz::data::{suite, Field, Scale};
use vecsz::stream::{
    compress_chunked, compress_stream, decompress_chunked, decompress_stream, StreamCompressor,
};
use vecsz::util::{bytes_to_f32, f32_as_bytes};

fn max_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (*x as f64 - *y as f64).abs()).fold(0.0, f64::max)
}

fn cesm_slab(rows: usize, cols: usize) -> Field {
    let ds = suite("cesm", Scale::Small, 11).unwrap();
    let f = &ds.fields[0];
    let stride = f.dims.shape[1];
    let mut data = Vec::with_capacity(rows * cols);
    for i in 0..rows {
        data.extend_from_slice(&f.data[i * stride..i * stride + cols]);
    }
    Field::new("CLDHGH-slab", Dims::d2(rows, cols), data)
}

fn walk_field(rows: usize, cols: usize, seed: u64) -> Field {
    let mut rng = vecsz::util::prng::Pcg32::seeded(seed);
    let mut x = 0.5f32;
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    Field::new("walk", Dims::d2(rows, cols), data)
}

#[test]
fn acceptance_streamed_field_four_chunks_bounded_and_thread_invariant() {
    let field = cesm_slab(160, 256);
    let eb = 1e-3;
    let cfg = Config { eb: EbMode::Abs(eb), threads: 2, ..Config::default() };

    // stream in small row batches: the compressor never sees the full field
    let mut sc = StreamCompressor::new(Vec::new(), field.dims, &cfg, 32).unwrap();
    for rows in field.data.chunks(8 * 256) {
        sc.push(rows).unwrap();
    }
    let (container, stats) = sc.finish().unwrap();
    assert!(stats.n_chunks >= 4, "expected >= 4 chunks, got {}", stats.n_chunks);
    assert_eq!(stats.n_elements, field.data.len());

    // serial and chunk-parallel (threads=4) decode: byte-identical
    let serial = decompress_chunked(&container, 1).unwrap();
    let parallel = decompress_chunked(&container, 4).unwrap();
    assert_eq!(serial.data, parallel.data, "thread count changed the decoded field");
    assert_eq!(serial.dims, field.dims);

    // error bound holds end to end
    assert!(max_err(&field.data, &serial.data) <= eb + 1e-6);

    // and the generic decompress entry point handles the v2 container
    let via_generic = decompress(&container, 4).unwrap();
    assert_eq!(via_generic.data, serial.data);
}

#[test]
fn io_reader_writer_roundtrip_bounded_memory() {
    let field = walk_field(96, 128, 5);
    let cfg = Config { eb: EbMode::Abs(1e-3), threads: 3, ..Config::default() };
    let raw = f32_as_bytes(&field.data).to_vec();

    let mut container = Vec::new();
    let stats = compress_stream(&raw[..], &mut container, field.dims, &cfg, 16).unwrap();
    assert!(stats.n_chunks >= 4);

    let mut out = Vec::new();
    let header = decompress_stream(&container[..], &mut out, 4).unwrap();
    assert_eq!(header.header.dims, field.dims);
    let rec = bytes_to_f32(&out);
    assert!(max_err(&field.data, &rec) <= 1e-3 + 1e-6);
}

#[test]
fn pipelined_compression_is_deterministic_across_thread_counts() {
    let field = walk_field(128, 64, 7);
    let mk = |threads| {
        let cfg = Config { eb: EbMode::Abs(1e-3), threads, ..Config::default() };
        compress_chunked(&field, &cfg, 16).unwrap().0
    };
    let one = mk(1);
    assert_eq!(one, mk(2), "2-thread pipeline changed the container bytes");
    assert_eq!(one, mk(8), "8-thread pipeline changed the container bytes");
}

#[test]
fn corrupted_chunked_container_never_panics() {
    let field = walk_field(64, 64, 9);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, _) = compress_chunked(&field, &cfg, 16).unwrap();
    assert!(decompress(&container, 1).is_ok());
    for at in (0..container.len()).step_by(53) {
        let mut bad = container.clone();
        bad[at] ^= 0xFF;
        // must be Err or (for flips that only touch dead framing slack) a
        // field of unchanged shape — never a panic
        if let Ok(rec) = decompress(&bad, 2) {
            assert_eq!(rec.data.len(), field.data.len(), "flip at {at}");
        }
    }
    for cut in [3, 40, 57, container.len() / 3, container.len() - 2] {
        assert!(decompress(&container[..cut], 1).is_err(), "cut {cut} accepted");
    }
}

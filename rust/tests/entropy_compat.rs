//! Committed-bytes backward-compatibility sweep for the entropy engine
//! (ISSUE-10): the three payload formats ever written by `vecsz::huffman`
//! — legacy unframed, HUF2 shared-table chunked, HUF3 framed (per-chunk
//! tables + gap arrays) — must all decode bit-exactly through the one
//! `decompress_u16` entry point, forever.
//!
//! The fixtures under `tests/fixtures/entropy/` are committed bytes, not
//! regenerated at test time: a format change that silently breaks old
//! containers cannot also silently rewrite the fixtures. They were
//! produced (and independently decode-verified) by `generate.py` next to
//! them, a bit-exact Python replica of the encoders; the
//! [`reencoding_reproduces_the_committed_bytes`] test closes the loop by
//! asserting today's Rust encoders still produce exactly these bytes.
//!
//! The fixture stream uses an inline integer-only LCG rather than the
//! crate's `Pcg32` so that the replica needs no float semantics.

use vecsz::bitio::get_uvarint;
use vecsz::coordinator::pool::ThreadPool;
use vecsz::huffman::{self, EntropyOptions, CHUNK_SYMS, GAP_INTERVAL_SYMS, HUF3_MAGIC};

const LEGACY: &[u8] = include_bytes!("fixtures/entropy/legacy.bin");
const HUF2: &[u8] = include_bytes!("fixtures/entropy/huf2.bin");
const HUF3: &[u8] = include_bytes!("fixtures/entropy/huf3.bin");

const ALPHABET: usize = 1024;

/// The non-stationary fixture stream: three Huffman chunks, each
/// concentrated on a different symbol neighborhood (so the HUF3 local
///-table gate engages), the last one a partial chunk barely past one gap
/// interval. Mirrored line for line by `fixture_stream()` in generate.py.
fn fixture_stream() -> Vec<u16> {
    let n = 2 * CHUNK_SYMS + 4321;
    let mut state: u64 = 0x5EED_2026;
    (0..n)
        .map(|i| {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let r = (state >> 33) as u32;
            let center = [512u16, 200, 800][i / CHUNK_SYMS];
            match r % 100 {
                0..=79 => center,
                80..=94 => center - 1 + (r / 100 % 3) as u16,
                _ => center - 8 + (r / 1000 % 16) as u16,
            }
        })
        .collect()
}

/// Walk a HUF3 header with the public primitives only and return, per
/// chunk, the absolute byte range of its gap blob (empty when the chunk
/// has none) plus the payload end. A deliberately independent re-parse:
/// the corruption sweep must not trust the decoder under test to locate
/// the bytes it is about to corrupt.
fn huf3_gap_regions(blob: &[u8]) -> (Vec<std::ops::Range<usize>>, usize) {
    assert!(blob.starts_with(&HUF3_MAGIC));
    let body = &blob[HUF3_MAGIC.len()..];
    let (_, mut pos) = huffman::read_lengths(body).unwrap();
    let mut varint = |pos: &mut usize| {
        let (v, n) = get_uvarint(&body[*pos..]).unwrap();
        *pos += n;
        v
    };
    let _chunk_syms = varint(&mut pos);
    let _gap_interval = varint(&mut pos);
    let n_chunks = varint(&mut pos) as usize;
    // entry fields: flags u8, sym_count, bit_len, [table_len], [gap_len]
    let mut entries = Vec::with_capacity(n_chunks);
    for _ in 0..n_chunks {
        let flags = body[pos];
        pos += 1;
        let _sym_count = varint(&mut pos);
        let bit_len = varint(&mut pos);
        let table_len = if flags & 1 != 0 { varint(&mut pos) as usize } else { 0 };
        let gap_len = if flags & 2 != 0 { varint(&mut pos) as usize } else { 0 };
        entries.push((table_len, gap_len, bit_len.div_ceil(8) as usize));
    }
    let mut off = HUF3_MAGIC.len() + pos;
    let mut regions = Vec::with_capacity(n_chunks);
    for (table_len, gap_len, stream_len) in entries {
        let gap_lo = off + table_len;
        regions.push(gap_lo..gap_lo + gap_len);
        off = gap_lo + gap_len + stream_len;
    }
    (regions, off)
}

#[test]
fn committed_payloads_decode_bit_exactly_through_one_entry_point() {
    let want = fixture_stream();
    for (name, blob) in [("legacy", LEGACY), ("huf2", HUF2), ("huf3", HUF3)] {
        assert_eq!(
            huffman::decompress_u16(blob).unwrap(),
            want,
            "{name} fixture diverged under the serial decode"
        );
        for nthreads in [1usize, 2, 7] {
            let pool = ThreadPool::new(nthreads);
            assert_eq!(
                huffman::decompress_u16_pooled(blob, Some(&pool)).unwrap(),
                want,
                "{name} fixture diverged at {nthreads} threads"
            );
        }
    }
}

#[test]
fn reencoding_reproduces_the_committed_bytes() {
    let syms = fixture_stream();
    assert_eq!(huffman::compress_u16(&syms, ALPHABET), LEGACY, "legacy encoder drifted");
    assert_eq!(
        huffman::compress_u16_chunked(&syms, ALPHABET, None),
        HUF2,
        "HUF2 encoder drifted"
    );
    let framed = huffman::compress_u16_framed(&syms, ALPHABET, None, &EntropyOptions::default());
    assert_eq!(framed, HUF3, "HUF3 encoder (default options) drifted");
    // and pooled encode stays byte-identical to the committed bytes too
    let pool = ThreadPool::new(3);
    assert_eq!(
        huffman::compress_u16_framed(&syms, ALPHABET, Some(&pool), &EntropyOptions::default()),
        HUF3,
        "pooled HUF3 encode diverged from the committed bytes"
    );
}

#[test]
fn huf3_fixture_carries_local_tables_and_gap_arrays() {
    let info = huffman::inspect_payload(HUF3).unwrap();
    assert_eq!(info.framing, "huf3");
    assert_eq!(info.n_chunks, 3);
    assert_eq!(info.total_syms, (2 * CHUNK_SYMS + 4321) as u64);
    // every chunk of the non-stationary stream beats the shared table
    assert_eq!(info.local_tables, 3);
    // two full chunks split at every gap interval, the 4321-symbol tail
    // still splits once (4321 > GAP_INTERVAL_SYMS)
    let want_segments = 2 * CHUNK_SYMS.div_ceil(GAP_INTERVAL_SYMS) + 2;
    assert_eq!(info.segments, want_segments);
    // the other fixtures classify as what they are
    assert_eq!(huffman::inspect_payload(HUF2).unwrap().framing, "huf2");
    assert_eq!(huffman::inspect_payload(LEGACY).unwrap().framing, "legacy");
}

#[test]
fn gap_array_corruption_always_errors_never_panics_or_misdecodes() {
    let (regions, payload_end) = huf3_gap_regions(HUF3);
    assert_eq!(payload_end, HUF3.len(), "independent header walk lost sync");
    assert_eq!(regions.len(), 3);
    for (ci, r) in regions.iter().enumerate() {
        assert!(r.len() >= 5, "chunk {ci} lost its gap array");
        for at in r.clone() {
            let mut bad = HUF3.to_vec();
            bad[at] ^= 0xA5;
            // serial and pooled alike: a flipped resync point (or its CRC)
            // must be rejected before any segment decodes
            assert!(
                huffman::decompress_u16(&bad).is_err(),
                "chunk {ci}: gap-blob flip at byte {at} accepted"
            );
            let pool = ThreadPool::new(2);
            assert!(
                huffman::decompress_u16_pooled(&bad, Some(&pool)).is_err(),
                "chunk {ci}: gap-blob flip at byte {at} accepted (pooled)"
            );
        }
    }
}

#[test]
fn truncated_fixtures_error_cleanly() {
    for (name, blob) in [("legacy", LEGACY), ("huf2", HUF2), ("huf3", HUF3)] {
        for cut in [0usize, 1, 3, 4, 16, blob.len() / 4, blob.len() / 2, blob.len() - 1] {
            assert!(
                huffman::decompress_u16(&blob[..cut]).is_err(),
                "{name} cut at {cut} accepted"
            );
        }
    }
}

/// Rewrite the fixtures from the Rust encoders. Ignored: committed bytes
/// must never move silently — run it on purpose
/// (`cargo test --test entropy_compat regenerate -- --ignored`) after an
/// intentional format revision, and update generate.py to match.
#[test]
#[ignore]
fn regenerate_fixtures() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/entropy");
    let syms = fixture_stream();
    std::fs::write(dir.join("legacy.bin"), huffman::compress_u16(&syms, ALPHABET)).unwrap();
    std::fs::write(dir.join("huf2.bin"), huffman::compress_u16_chunked(&syms, ALPHABET, None))
        .unwrap();
    std::fs::write(
        dir.join("huf3.bin"),
        huffman::compress_u16_framed(&syms, ALPHABET, None, &EntropyOptions::default()),
    )
    .unwrap();
}

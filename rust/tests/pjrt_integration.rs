//! Integration: AOT artifacts (L1/L2) vs native Rust backends (L3).
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially, with a note on stderr) when `artifacts/manifest.json` is
//! absent so `cargo test` works in a fresh checkout.

use std::path::Path;

use vecsz::blocks::BlockShape;
use vecsz::padding::{PadGranularity, PadScalars, PadValue, PaddingPolicy};
use vecsz::quant::psz::PszBackend;
use vecsz::quant::{DqConfig, PqBackend};
use vecsz::runtime::{PjrtBackend, PjrtRuntime};
use vecsz::util::prng::Pcg32;

fn artifact_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping PJRT integration test: artifacts/ not built");
        None
    }
}

fn random_batch(shape: BlockShape, nb: usize, seed: u64) -> (Vec<f32>, PadScalars) {
    let elems = shape.elems();
    let mut rng = Pcg32::seeded(seed);
    let mut blocks = vec![0.0f32; nb * elems];
    let mut x = 0.0f32;
    for v in blocks.iter_mut() {
        x += (rng.next_f32() - 0.5) * 0.2;
        *v = x;
    }
    let scalars: Vec<f32> = (0..nb)
        .map(|b| {
            let s = &blocks[b * elems..(b + 1) * elems];
            s.iter().sum::<f32>() / elems as f32
        })
        .collect();
    (
        blocks,
        PadScalars {
            policy: PaddingPolicy::new(PadValue::Avg, PadGranularity::Block),
            scalars,
            ndim: shape.ndim,
        },
    )
}

fn compare_backend_outputs(ndim: usize, bs: usize, lanes: usize, rt: &PjrtRuntime) {
    let shape = BlockShape::new(ndim, bs);
    let cfg = DqConfig::new(1e-3, 512, shape);
    // more blocks than one superbatch would be slow under test; use a
    // modest batch that still exercises the tail-padding path.
    let nb = 11;
    let (blocks, pads) = random_batch(shape, nb, 42 + ndim as u64);

    let pjrt = match PjrtBackend::new(rt, ndim, bs, lanes) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping ndim={ndim} bs={bs} lanes={lanes}: {e}");
            return;
        }
    };
    let elems = shape.elems();
    let mut c_native = vec![0u16; nb * elems];
    let mut v_native = vec![0.0f32; nb * elems];
    PszBackend.run(&cfg, &blocks, 0, &pads, &mut c_native, &mut v_native);
    let mut c_pjrt = vec![0u16; nb * elems];
    let mut v_pjrt = vec![0.0f32; nb * elems];
    pjrt.run(&cfg, &blocks, 0, &pads, &mut c_pjrt, &mut v_pjrt);

    assert_eq!(c_native, c_pjrt, "codes diverge: ndim={ndim} bs={bs} lanes={lanes}");
    assert_eq!(v_native, v_pjrt, "outlier values diverge: ndim={ndim} bs={bs} lanes={lanes}");
}

#[test]
fn pjrt_jnp_artifacts_match_native_all_dims() {
    let Some(dir) = artifact_dir() else { return };
    let rt = PjrtRuntime::new(dir).expect("pjrt runtime");
    // smallest config per dim keeps compile time reasonable in tests
    compare_backend_outputs(1, 64, 8, &rt);
    compare_backend_outputs(2, 16, 8, &rt);
    compare_backend_outputs(3, 8, 8, &rt);
}

#[test]
fn pjrt_pallas_artifact_matches_native_1d() {
    let Some(dir) = artifact_dir() else { return };
    let rt = PjrtRuntime::new(dir).expect("pjrt runtime");
    let Some(meta) = rt.manifest.find(1, 64, 8, "pallas").cloned() else {
        eprintln!("no pallas artifact; skipping");
        return;
    };
    let shape = BlockShape::new(1, 64);
    let cfg = DqConfig::new(1e-3, 512, shape);
    let nb = 7;
    let (blocks, pads) = random_batch(shape, nb, 99);
    let pjrt = PjrtBackend::from_meta(&rt, &meta).expect("load pallas artifact");
    let elems = shape.elems();
    let mut c_native = vec![0u16; nb * elems];
    let mut v_native = vec![0.0f32; nb * elems];
    PszBackend.run(&cfg, &blocks, 0, &pads, &mut c_native, &mut v_native);
    let mut c_p = vec![0u16; nb * elems];
    let mut v_p = vec![0.0f32; nb * elems];
    pjrt.run(&cfg, &blocks, 0, &pads, &mut c_p, &mut v_p);
    assert_eq!(c_native, c_p, "pallas kernel diverges from native dual-quant");
    assert_eq!(v_native, v_p);
}

#[test]
fn manifest_covers_paper_config_grid() {
    let Some(dir) = artifact_dir() else { return };
    let rt = PjrtRuntime::new(dir).expect("pjrt runtime");
    for ndim in 1..=3 {
        let configs = rt.manifest.configs(ndim);
        assert!(
            configs.len() >= 2,
            "expected >= 2 jnp configs for ndim={ndim}, got {configs:?}"
        );
        // both lane widths present (the paper's AVX2/AVX-512 axis)
        assert!(configs.iter().any(|&(_, l)| l == 8));
        assert!(configs.iter().any(|&(_, l)| l == 16));
    }
}

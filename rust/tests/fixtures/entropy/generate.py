#!/usr/bin/env python3
"""Regenerate the committed entropy-compat fixtures.

Bit-exact Python replica of the three payload formats written by
`rust/src/huffman` (legacy unframed, HUF2 chunked, HUF3 framed), used to
produce `legacy.bin` / `huf2.bin` / `huf3.bin` from the deterministic
fixture stream defined in `rust/tests/entropy_compat.rs`. The Rust test
asserts the committed bytes equal the Rust encoders' output AND decode to
the fixture stream, so any honest drift between this replica and the Rust
implementation fails CI loudly.

The replica mirrors, exactly:
  * the LCG fixture stream (same multiplier/increment/seed as the test),
  * heap Huffman code lengths (heapq over (weight, node) tuples pops in
    the same order as Rust's BinaryHeap<Reverse<(u64, usize)>>; internal
    node ids count up from `alphabet` in merge order),
  * canonical code assignment + LSB-first bit packing,
  * the sparse (delta-symbol, length) table header and LEB128 varints,
  * HUF2/HUF3 framing incl. the per-chunk local-table size gate
    (LOCAL_TABLE_MIN_GAIN) and CRC32-guarded gap arrays.

The generator refuses to write fixtures whose code depths exceed MAX_BITS:
the Rust Kraft-repair path is NOT replicated here, and the fixture stream
is chosen so it never runs.

Every fixture is decoded back and compared against the stream before
anything is written.
"""

import struct
import zlib
from heapq import heappush, heappop
from pathlib import Path

MAX_BITS = 15
CHUNK_SYMS = 1 << 16
GAP_INTERVAL = 4096
LOCAL_TABLE_MIN_GAIN = 64
ALPHABET = 1024
HUF2_MAGIC = bytes([0xF5, ord("H"), ord("F"), ord("2")])
HUF3_MAGIC = bytes([0xF7, ord("H"), ord("F"), ord("3")])
MASK64 = (1 << 64) - 1


def fixture_stream():
    """Mirror of `fixture_stream()` in entropy_compat.rs (integer-only)."""
    n = 2 * CHUNK_SYMS + 4321
    state = 0x5EED2026
    out = []
    for i in range(n):
        state = (state * 6364136223846793005 + 1442695040888963407) & MASK64
        r = state >> 33
        center = (512, 200, 800)[i // CHUNK_SYMS]
        m = r % 100
        if m <= 79:
            sym = center
        elif m <= 94:
            sym = center - 1 + (r // 100) % 3
        else:
            sym = center - 8 + (r // 1000) % 16
        out.append(sym)
    return out


def put_uvarint(out, v):
    while v >= 0x80:
        out.append((v & 0x7F) | 0x80)
        v >>= 7
    out.append(v)


def histogram(syms):
    h = [0] * ALPHABET
    for s in syms:
        h[s] += 1
    return h


def code_lengths(freqs):
    n = len(freqs)
    lens = [0] * n
    present = [i for i in range(n) if freqs[i] > 0]
    if not present:
        return lens
    if len(present) == 1:
        lens[present[0]] = 1
        return lens
    heap = []
    parent = {}
    next_internal = n
    for i in present:
        heappush(heap, (freqs[i], i))
    while len(heap) > 1:
        wa, a = heappop(heap)
        wb, b = heappop(heap)
        p = next_internal
        next_internal += 1
        parent[a] = p
        parent[b] = p
        heappush(heap, (wa + wb, p))
    root = heap[0][1]
    for i in present:
        d, node = 0, i
        while node != root:
            node = parent[node]
            d += 1
        lens[i] = d
    assert all(lens[i] <= MAX_BITS for i in present), (
        "fixture stream needs the Kraft repair path, which this replica "
        "does not implement — pick a tamer distribution"
    )
    return lens


def canonical_codes(lens):
    max_len = max(lens) if lens else 0
    bl_count = [0] * (max_len + 1)
    for l in lens:
        if l > 0:
            bl_count[l] += 1
    next_code = [0] * (max_len + 2)
    code = 0
    for bits in range(1, max_len + 1):
        code = (code + bl_count[bits - 1]) << 1
        next_code[bits] = code
    out = [(0, 0)] * len(lens)
    for bits in range(1, max_len + 1):
        for sym, l in enumerate(lens):
            if l == bits:
                out[sym] = (next_code[bits], l)
                next_code[bits] += 1
    return out


def reverse_bits(v, n):
    r = 0
    for _ in range(n):
        r = (r << 1) | (v & 1)
        v >>= 1
    return r


class Enc:
    """symbol -> (LSB-first reversed code, length), plus cost accounting."""

    def __init__(self, lens):
        self.lens = lens
        self.table = [
            (reverse_bits(c, l), l) if l else (0, 0) for c, l in canonical_codes(lens)
        ]

    def cost_bits(self, hist):
        return sum(f * self.table[s][1] for s, f in enumerate(hist))


class BitW:
    """LSB-first bit writer (semantically identical to bitio::BitWriter)."""

    def __init__(self):
        self.out = bytearray()
        self.acc = 0
        self.nbits = 0

    def put(self, v, n):
        self.acc |= v << self.nbits
        self.nbits += n
        while self.nbits >= 8:
            self.out.append(self.acc & 0xFF)
            self.acc >>= 8
            self.nbits -= 8

    def bit_len(self):
        return len(self.out) * 8 + self.nbits

    def finish(self):
        if self.nbits:
            self.out.append(self.acc & 0xFF)
            self.acc = 0
            self.nbits = 0
        return bytes(self.out)


def encode_chunk_gaps(enc, syms, gap_interval):
    """Returns (stream bytes, exact bit length, gap offsets)."""
    w = BitW()
    gaps = []
    for lo in range(0, len(syms), gap_interval) if gap_interval else [0]:
        if lo > 0:
            gaps.append(w.bit_len())
        for s in syms[lo : lo + gap_interval] if gap_interval else syms:
            code, l = enc.table[s]
            assert l > 0
            w.put(code, l)
    bits = w.bit_len()
    return w.finish(), bits, gaps


def write_lengths(out, lens):
    pairs = [(s, l) for s, l in enumerate(lens) if l > 0]
    put_uvarint(out, len(lens))
    put_uvarint(out, len(pairs))
    prev = 0
    for s, l in pairs:
        put_uvarint(out, s - prev)
        out.append(l)
        prev = s


def compress_legacy(syms):
    lens = code_lengths(histogram(syms))
    enc = Enc(lens)
    out = bytearray()
    write_lengths(out, lens)
    put_uvarint(out, len(syms))
    stream, _, _ = encode_chunk_gaps(enc, syms, 0)
    out += stream
    return bytes(out)


def compress_huf2(syms):
    lens = code_lengths(histogram(syms))
    enc = Enc(lens)
    chunks = [
        encode_chunk_gaps(enc, syms[lo : lo + CHUNK_SYMS], 0)
        for lo in range(0, len(syms), CHUNK_SYMS)
    ]
    out = bytearray(HUF2_MAGIC)
    write_lengths(out, lens)
    put_uvarint(out, CHUNK_SYMS)
    put_uvarint(out, len(chunks))
    for i, (_, bits, _) in enumerate(chunks):
        lo = i * CHUNK_SYMS
        put_uvarint(out, min(lo + CHUNK_SYMS, len(syms)) - lo)
        put_uvarint(out, bits)
    for stream, _, _ in chunks:
        out += stream
    return bytes(out)


def compress_huf3(syms):
    shared_lens = code_lengths(histogram(syms))
    shared = Enc(shared_lens)
    framed = []  # (flags, table bytes, gap bytes, stream bytes, bits, count)
    for lo in range(0, len(syms), CHUNK_SYMS):
        chunk = syms[lo : lo + CHUNK_SYMS]
        ch_hist = histogram(chunk)
        flags, table, enc = 0, b"", shared
        # the size gate, byte for byte as in compress_u16_framed
        shared_bytes = -(-shared.cost_bits(ch_hist) // 8)
        local_lens = code_lengths(ch_hist)
        hdr = bytearray()
        write_lengths(hdr, local_lens)
        local = Enc(local_lens)
        local_bytes = -(-local.cost_bits(ch_hist) // 8) + len(hdr)
        if local_bytes + LOCAL_TABLE_MIN_GAIN <= shared_bytes:
            flags |= 1
            table = bytes(hdr)
            enc = local
        gap = GAP_INTERVAL if len(chunk) > GAP_INTERVAL else 0
        stream, bits, gaps = encode_chunk_gaps(enc, chunk, gap)
        gapbytes = b""
        if gaps:
            flags |= 2
            blob = bytearray()
            put_uvarint(blob, len(gaps))
            prev = 0
            for off in gaps:
                put_uvarint(blob, off - prev)
                prev = off
            gapbytes = struct.pack("<I", zlib.crc32(bytes(blob))) + bytes(blob)
        framed.append((flags, table, gapbytes, stream, bits, len(chunk)))
    out = bytearray(HUF3_MAGIC)
    write_lengths(out, shared_lens)
    put_uvarint(out, CHUNK_SYMS)
    put_uvarint(out, GAP_INTERVAL)
    put_uvarint(out, len(framed))
    for flags, table, gapbytes, _, bits, count in framed:
        out.append(flags)
        put_uvarint(out, count)
        put_uvarint(out, bits)
        if flags & 1:
            put_uvarint(out, len(table))
        if flags & 2:
            put_uvarint(out, len(gapbytes))
    for flags, table, gapbytes, stream, _, _ in framed:
        out += table + gapbytes + stream
    return bytes(out)


# ---------------------------------------------------------------- verify


class BitR:
    def __init__(self, data, skip_bits=0):
        self.data = data
        self.pos = 0
        self.acc = 0
        self.nbits = 0
        if skip_bits:
            assert self.get(skip_bits) is not None

    def get(self, n):
        while self.nbits < n and self.pos < len(self.data):
            self.acc |= self.data[self.pos] << self.nbits
            self.pos += 1
            self.nbits += 8
        if self.nbits < n:
            return None
        v = self.acc & ((1 << n) - 1)
        self.acc >>= n
        self.nbits -= n
        return v

    def consumed_bits(self):
        return self.pos * 8 - self.nbits


def decode_stream(lens, data, count, skip_bits=0):
    """Slow reference decode; returns (symbols, bits consumed past skip)."""
    by_rev = {
        (reverse_bits(c, l), l): s
        for s, (c, l) in enumerate(canonical_codes(lens))
        if l > 0
    }
    r = BitR(data, skip_bits)
    out = []
    while len(out) < count:
        code, ok = 0, False
        for l in range(1, MAX_BITS + 1):
            code |= r.get(1) << (l - 1)
            if (code, l) in by_rev:
                out.append(by_rev[(code, l)])
                ok = True
                break
        assert ok, "reference decode lost sync"
    return out, r.consumed_bits() - skip_bits


def get_uvarint_at(data, pos):
    v, shift = 0, 0
    while True:
        b = data[pos]
        pos += 1
        v |= (b & 0x7F) << shift
        if not b & 0x80:
            return v, pos
        shift += 7


def read_lengths_at(data, pos):
    alphabet, pos = get_uvarint_at(data, pos)
    npairs, pos = get_uvarint_at(data, pos)
    lens, sym = [0] * alphabet, 0
    for i in range(npairs):
        delta, pos = get_uvarint_at(data, pos)
        sym = delta if i == 0 else sym + delta
        lens[sym] = data[pos]
        pos += 1
    return lens, pos


def verify_legacy(blob, syms):
    lens, pos = read_lengths_at(blob, 0)
    count, pos = get_uvarint_at(blob, pos)
    assert count == len(syms)
    out, _ = decode_stream(lens, blob[pos:], count)
    assert out == syms, "legacy fixture does not decode to the stream"


def verify_huf2(blob, syms):
    assert blob[:4] == HUF2_MAGIC
    lens, pos = read_lengths_at(blob, 4)
    chunk_syms, pos = get_uvarint_at(blob, pos)
    n_chunks, pos = get_uvarint_at(blob, pos)
    assert chunk_syms == CHUNK_SYMS
    table = []
    for _ in range(n_chunks):
        count, pos = get_uvarint_at(blob, pos)
        bits, pos = get_uvarint_at(blob, pos)
        table.append((count, bits))
    out, off = [], pos
    for count, bits in table:
        nbytes = -(-bits // 8)
        part, used = decode_stream(lens, blob[off : off + nbytes], count)
        assert used == bits, "chunk bit length mismatch"
        out += part
        off += nbytes
    assert off == len(blob) and out == syms, "huf2 fixture does not decode"


def verify_huf3(blob, syms):
    assert blob[:4] == HUF3_MAGIC
    shared_lens, pos = read_lengths_at(blob, 4)
    chunk_syms, pos = get_uvarint_at(blob, pos)
    gap_interval, pos = get_uvarint_at(blob, pos)
    n_chunks, pos = get_uvarint_at(blob, pos)
    assert (chunk_syms, gap_interval) == (CHUNK_SYMS, GAP_INTERVAL)
    entries = []
    for _ in range(n_chunks):
        flags = blob[pos]
        pos += 1
        count, pos = get_uvarint_at(blob, pos)
        bits, pos = get_uvarint_at(blob, pos)
        table_len = gap_len = 0
        if flags & 1:
            table_len, pos = get_uvarint_at(blob, pos)
        if flags & 2:
            gap_len, pos = get_uvarint_at(blob, pos)
        entries.append((flags, count, bits, table_len, gap_len))
    out, off = [], pos
    local_tables = segments = 0
    for flags, count, bits, table_len, gap_len in entries:
        lens = shared_lens
        if flags & 1:
            local_tables += 1
            lens, used = read_lengths_at(blob[off : off + table_len], 0)
            assert used == table_len
            off += table_len
        bounds = [0]
        if flags & 2:
            gapblob = blob[off : off + gap_len]
            off += gap_len
            assert struct.unpack("<I", gapblob[:4])[0] == zlib.crc32(gapblob[4:])
            n_points, gpos = get_uvarint_at(gapblob, 4)
            assert n_points == -(-count // gap_interval) - 1
            prev = 0
            for _ in range(n_points):
                delta, gpos = get_uvarint_at(gapblob, gpos)
                prev += delta
                bounds.append(prev)
            assert gpos == len(gapblob)
        bounds.append(bits)
        nbytes = -(-bits // 8)
        stream = blob[off : off + nbytes]
        off += nbytes
        seg_syms = gap_interval if len(bounds) > 2 else count
        # decode every gap segment independently, as the parallel Rust
        # decoder does, proving the resync points are genuine
        for j in range(len(bounds) - 1):
            seg_count = min(seg_syms, count - j * seg_syms)
            span = bounds[j + 1] - bounds[j]
            part, used = decode_stream(
                lens,
                stream[bounds[j] // 8 : -(-bounds[j + 1] // 8)],
                seg_count,
                bounds[j] % 8,
            )
            assert used == span, "segment bit span mismatch"
            out += part
            segments += 1
    assert off == len(blob) and out == syms, "huf3 fixture does not decode"
    assert local_tables >= 1, "local-table gate never engaged"
    assert segments > n_chunks, "no chunk carried a gap array"
    return local_tables, segments


def main():
    here = Path(__file__).resolve().parent
    syms = fixture_stream()
    legacy = compress_legacy(syms)
    huf2 = compress_huf2(syms)
    huf3 = compress_huf3(syms)
    verify_legacy(legacy, syms)
    verify_huf2(huf2, syms)
    local_tables, segments = verify_huf3(huf3, syms)
    (here / "legacy.bin").write_bytes(legacy)
    (here / "huf2.bin").write_bytes(huf2)
    (here / "huf3.bin").write_bytes(huf3)
    print(
        f"wrote legacy={len(legacy)}B huf2={len(huf2)}B huf3={len(huf3)}B "
        f"(local_tables={local_tables}, segments={segments}, "
        f"n={len(syms)} symbols)"
    )


if __name__ == "__main__":
    main()

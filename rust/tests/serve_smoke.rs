//! `vsz serve` smoke tests: an in-process server on an ephemeral port,
//! driven by the library `Client` over real TCP.
//!
//! Covers the ISSUE-6 acceptance criteria for the service layer:
//! * ≥4 concurrent compress requests complete, and the returned container
//!   bytes are **bit-identical** to a local single-threaded
//!   `stream::compress_chunked` of the same field (the scheduler's
//!   byte-identity invariant holds across the wire);
//! * round-trip: server-side decompress of a server-built container
//!   returns the exact f32 bit pattern of a local decode;
//! * random-access extract of a row range matches the local slice;
//! * a `stats` request reflects the work done;
//! * a server with a tiny admission cap rejects with `busy` and stays
//!   usable afterwards.
//!
//! PR 8 adds the decoded-chunk cache gauges: a repeated extract of the
//! same container must be served from warm slabs (cache hits > 0) and
//! the `stats` JSON must expose the cache counters.

// The legacy StreamDecompressor decode methods are kept as deprecated
// wrappers over the Dataset region reads; this test pins the wire bytes
// against them on purpose.
#![allow(deprecated)]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::thread;

use vecsz::compressor::{Config, EbMode};
use vecsz::data::Field;
use vecsz::server::{
    is_busy, Client, ServeConfig, Server, KIND_END, KIND_ERROR, OP_SHUTDOWN, OP_STATS,
};
use vecsz::stream;
use vecsz::util::prng::Pcg32;

fn smooth_field(name: &str, rows: usize, cols: usize, seed: u64) -> Field {
    let dims = vecsz::blocks::Dims::d2(rows, cols);
    let mut rng = Pcg32::seeded(seed);
    let mut x = 0.0f32;
    let data: Vec<f32> = (0..dims.len())
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    Field::new(name, dims, data)
}

fn start_server(cfg: ServeConfig) -> (String, thread::JoinHandle<()>) {
    let srv = Server::bind("127.0.0.1:0", cfg).expect("bind ephemeral port");
    let addr = srv.local_addr().unwrap().to_string();
    let h = thread::spawn(move || srv.run().expect("server run"));
    (addr, h)
}

fn local_reference(field: &Field, eb: f64, span: usize) -> Vec<u8> {
    let cfg = Config { eb: EbMode::Abs(eb), threads: 1, ..Config::default() };
    let (bytes, _) = stream::compress_chunked(field, &cfg, span).expect("local reference");
    bytes
}

#[test]
fn concurrent_requests_roundtrip_bit_exactly() {
    const EB: f64 = 1e-3;
    const SPAN: usize = 16;
    let (addr, server) = start_server(ServeConfig { threads: 2, ..ServeConfig::default() });

    // 5 clients compress distinct fields concurrently over separate
    // connections — more requests in flight than pool threads.
    let workers: Vec<_> = (0..5)
        .map(|i| {
            let addr = addr.clone();
            thread::spawn(move || {
                let field = smooth_field(&format!("f{i}"), 64 + 16 * i, 48, 0x5EED + i as u64);
                let dims = format!("{}x{}", 64 + 16 * i, 48);
                let mut c = Client::connect(&addr).expect("connect");
                let (bytes, end) =
                    c.compress(&field.name, &dims, EB, SPAN, &field.data).expect("compress");
                assert!(end.contains("\"op\":\"compress\""), "end frame: {end}");
                (field, bytes)
            })
        })
        .collect();

    for w in workers {
        let (field, served) = w.join().expect("client thread");
        let reference = local_reference(&field, EB, SPAN);
        assert_eq!(
            served, reference,
            "{}: server container must be bit-identical to the local serial writer",
            field.name
        );
    }

    // round-trip one container through the server decoder and compare the
    // exact f32 bit pattern against the local decode path
    let field = smooth_field("rt", 96, 48, 7);
    let mut c = Client::connect(&addr).expect("connect");
    let (container, _) = c.compress("rt", "96x48", EB, SPAN, &field.data).expect("compress");
    let (decoded, end) = c.decompress(&container).expect("decompress");
    assert!(end.contains("\"op\":\"decompress\""), "end frame: {end}");
    let local = vecsz::compressor::decompress(&container, 1).expect("local decode");
    assert_eq!(decoded.len(), local.data.len());
    for (k, (a, b)) in decoded.iter().zip(local.data.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "value {k} differs from the local decode");
    }
    for (k, (a, b)) in decoded.iter().zip(field.data.iter()).enumerate() {
        assert!((a - b).abs() <= EB as f32 * 1.0001, "value {k} breaks the bound");
    }

    // random access: rows 20..52 span two chunks; must equal the local
    // row-range decode bit for bit
    let (rows, end) = c.extract(&container, 20, 52).expect("extract");
    assert!(end.contains("\"op\":\"extract\""), "end frame: {end}");
    let mut dec = stream::StreamDecompressor::new(std::io::Cursor::new(&container[..])).unwrap();
    let local_rows = dec.decode_rows(20..52, 1).unwrap();
    assert_eq!(rows.len(), local_rows.len());
    for (a, b) in rows.iter().zip(local_rows.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // a repeated extract of the same container is served from the warm
    // decoded-chunk cache and stays bit-identical
    let (rows_warm, _) = c.extract(&container, 20, 52).expect("warm extract");
    assert_eq!(rows_warm.len(), rows.len());
    for (a, b) in rows_warm.iter().zip(rows.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "warm extract must match the cold one");
    }

    // lifetime stats reflect everything the server has done
    let stats = c.stats().expect("stats");
    let j = vecsz::util::json::parse(&stats).expect("stats json parses");
    let lifetime = j.get("stats").expect("lifetime aggregate");
    let compress_ops = lifetime.get("compress_ops").and_then(|v| v.as_f64()).unwrap();
    assert!(compress_ops >= 6.0, "expected >= 6 compress ops, stats: {stats}");
    assert_eq!(lifetime.get("decompress_ops").and_then(|v| v.as_f64()), Some(1.0));
    assert_eq!(lifetime.get("extract_ops").and_then(|v| v.as_f64()), Some(2.0));
    assert_eq!(j.get("inflight_bytes").and_then(|v| v.as_f64()), Some(0.0));

    // decoded-chunk cache gauges: the first container read filled the
    // cache (misses), the repeated extract was served from warm slabs
    let budget = j.get("cache_budget_bytes").and_then(|v| v.as_f64()).expect("budget gauge");
    assert!(budget > 0.0, "default serve cache budget must be non-zero, stats: {stats}");
    let cache = j.get("cache").expect("cache gauge object");
    let hits = cache.get("hits").and_then(|v| v.as_f64()).unwrap();
    let misses = cache.get("misses").and_then(|v| v.as_f64()).unwrap();
    let resident = cache.get("resident_bytes").and_then(|v| v.as_f64()).unwrap();
    assert!(misses >= 1.0, "cold extract must register cache misses, stats: {stats}");
    assert!(hits >= 1.0, "warm extract must register cache hits, stats: {stats}");
    assert!(resident > 0.0 && resident <= budget, "resident bytes must be bounded: {stats}");

    c.shutdown().expect("shutdown");
    drop(c);
    server.join().expect("server thread exits after shutdown");
}

/// Hand-rolled framed request for malformed-input tests the library
/// `Client` cannot express; returns the first response frame.
fn raw_request(s: &mut TcpStream, op: u8, hdr: &[u8], body: &[u8]) -> Vec<u8> {
    let mut p = Vec::with_capacity(5 + hdr.len() + body.len());
    p.push(op);
    p.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
    p.extend_from_slice(hdr);
    p.extend_from_slice(body);
    s.write_all(&(p.len() as u32).to_le_bytes()).unwrap();
    s.write_all(&p).unwrap();
    s.flush().unwrap();
    let mut len = [0u8; 4];
    s.read_exact(&mut len).unwrap();
    let mut frame = vec![0u8; u32::from_le_bytes(len) as usize];
    s.read_exact(&mut frame).unwrap();
    frame
}

#[test]
fn non_utf8_header_gets_error_frame_and_connection_survives() {
    let (addr, server) = start_server(ServeConfig { threads: 1, ..ServeConfig::default() });
    let mut s = TcpStream::connect(&addr).expect("connect");
    // invalid UTF-8 header bytes: must get an error frame, not a hangup
    let frame = raw_request(&mut s, OP_STATS, &[0xff, 0xfe, 0xfd], &[]);
    assert_eq!(frame[0], KIND_ERROR, "frame: {frame:?}");
    let msg = String::from_utf8_lossy(&frame[1..]);
    assert!(msg.contains("UTF-8"), "unexpected error message: {msg}");
    // same connection keeps serving well-formed requests
    let frame = raw_request(&mut s, OP_STATS, b"{}", &[]);
    assert_eq!(frame[0], KIND_END, "connection must survive the bad header");
    let frame = raw_request(&mut s, OP_SHUTDOWN, b"{}", &[]);
    assert_eq!(frame[0], KIND_END);
    drop(s);
    server.join().expect("server thread exits");
}

#[test]
fn decoded_output_counts_against_admission_cap() {
    // A constant field compresses to a tiny container whose decoded output
    // (64*64*4 = 16384 bytes) dwarfs it; the cap sits between the two, so
    // admission must reject on the decoded size, not the wire bytes.
    let dims = vecsz::blocks::Dims::d2(64, 64);
    let field = Field::new("zeros", dims, vec![0.0f32; dims.len()]);
    let container = local_reference(&field, 1e-3, 64);
    assert!(
        (container.len() as u64) < 8192,
        "premise: compressed body ({} bytes) alone fits the cap",
        container.len()
    );
    let (addr, server) = start_server(ServeConfig {
        threads: 1,
        max_inflight_bytes: 8192,
        ..ServeConfig::default()
    });
    let mut c = Client::connect(&addr).expect("connect");
    let err = c.decompress(&container).unwrap_err();
    assert!(is_busy(&err), "expected busy on decoded-output size, got: {err}");
    // nothing leaked, and small work still runs
    let small = smooth_field("small", 8, 16, 9);
    let (bytes, _) = c.compress("small", "8x16", 1e-3, 8, &small.data).expect("fits");
    assert_eq!(bytes, local_reference(&small, 1e-3, 8));
    let stats = c.stats().expect("stats");
    let j = vecsz::util::json::parse(&stats).unwrap();
    assert_eq!(j.get("inflight_bytes").and_then(|v| v.as_f64()), Some(0.0), "stats: {stats}");
    c.shutdown().expect("shutdown");
    drop(c);
    server.join().expect("server thread exits");
}

#[test]
fn admission_cap_rejects_with_busy_and_recovers() {
    // cap far below one request's body: every compress is rejected busy
    let (addr, server) = start_server(ServeConfig {
        threads: 1,
        max_inflight_bytes: 1024,
        ..ServeConfig::default()
    });
    let field = smooth_field("big", 64, 64, 3);
    let mut c = Client::connect(&addr).expect("connect");
    let err = c.compress("big", "64x64", 1e-3, 16, &field.data).unwrap_err();
    assert!(is_busy(&err), "expected a busy rejection, got: {err}");

    // the connection survives the rejection: a request under the cap works
    let small = smooth_field("small", 8, 16, 4);
    let (bytes, _) = c.compress("small", "8x16", 1e-3, 8, &small.data).expect("fits under cap");
    assert_eq!(bytes, local_reference(&small, 1e-3, 8));

    // the rejected request must not leak admission budget
    let stats = c.stats().expect("stats");
    let j = vecsz::util::json::parse(&stats).unwrap();
    assert_eq!(j.get("inflight_bytes").and_then(|v| v.as_f64()), Some(0.0), "stats: {stats}");

    c.shutdown().expect("shutdown");
    drop(c);
    server.join().expect("server thread exits");
}

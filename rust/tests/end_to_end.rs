//! End-to-end integration over the public API: synthetic suites through
//! compress/decompress with every backend, checking the error bound, the
//! bitstream determinism and the padding-study claim on real-ish fields.

use vecsz::compressor::{compress, decompress, BackendChoice, Config, EbMode};
use vecsz::data::{suite, Scale};
use vecsz::metrics::distortion;
use vecsz::padding::{PadGranularity, PadValue, PaddingPolicy};

fn subsample(field: &vecsz::data::Field, max_elems: usize) -> vecsz::data::Field {
    // keep tests fast: slice a prefix that preserves dimensionality
    let d = field.dims;
    if d.len() <= max_elems {
        return field.clone();
    }
    match d.ndim {
        1 => vecsz::data::Field::new(
            field.name.clone(),
            vecsz::blocks::Dims::d1(max_elems),
            field.data[..max_elems].to_vec(),
        ),
        2 => {
            let rows = (max_elems / d.shape[1]).max(4).min(d.shape[0]);
            vecsz::data::Field::new(
                field.name.clone(),
                vecsz::blocks::Dims::d2(rows, d.shape[1]),
                field.data[..rows * d.shape[1]].to_vec(),
            )
        }
        _ => {
            let planes = (max_elems / (d.shape[1] * d.shape[2])).max(4).min(d.shape[0]);
            vecsz::data::Field::new(
                field.name.clone(),
                vecsz::blocks::Dims::d3(planes, d.shape[1], d.shape[2]),
                field.data[..planes * d.shape[1] * d.shape[2]].to_vec(),
            )
        }
    }
}

#[test]
fn every_suite_roundtrips_within_bound() {
    for name in ["hacc", "cesm", "hurricane", "nyx", "qmcpack"] {
        let ds = suite(name, Scale::Small, 1).unwrap();
        let field = subsample(&ds.fields[0], 200_000);
        // NYX density spans ~1e8: absolute bounds must scale with range.
        let cfg = Config { eb: EbMode::Rel(1e-4), ..Config::default() };
        let (bytes, stats) = compress(&field, &cfg).unwrap();
        let rec = decompress(&bytes, 1).unwrap();
        let d = distortion(&field.data, &rec.data);
        let tol = vecsz::metrics::roundtrip_tolerance(stats.eb, d.value_range);
        assert!(
            d.max_abs_err <= tol,
            "{name}: max err {} > tol {} (eb {})",
            d.max_abs_err,
            tol,
            stats.eb
        );
        assert!(stats.size.ratio() > 1.0, "{name}: ratio {:.2}", stats.size.ratio());
    }
}

#[test]
fn backends_produce_interchangeable_dualquant_streams() {
    // psz / vec8 / vec16 / simd8 / simd16 must produce byte-identical
    // containers — on every ISA the host can dispatch the simd kernel to
    let ds = suite("cesm", Scale::Small, 2).unwrap();
    let field = subsample(&ds.fields[1], 100_000);
    let mk = |backend| {
        let cfg = Config { backend, eb: EbMode::Abs(1e-3), ..Config::default() };
        compress(&field, &cfg).unwrap().0
    };
    let a = mk(BackendChoice::Psz);
    let b = mk(BackendChoice::Vec { width: 8 });
    let c = mk(BackendChoice::Vec { width: 16 });
    assert_eq!(a, b, "psz vs vec8 containers differ");
    assert_eq!(b, c, "vec8 vs vec16 containers differ");
    for isa in vecsz::simd::Isa::available() {
        vecsz::simd::force_isa(Some(isa));
        let s8 = mk(BackendChoice::Simd { width: 8 });
        let s16 = mk(BackendChoice::Simd { width: 16 });
        assert_eq!(a, s8, "psz vs simd8 containers differ on {}", isa.name());
        assert_eq!(a, s16, "psz vs simd16 containers differ on {}", isa.name());
    }
    vecsz::simd::force_isa(None);
}

#[test]
fn avg_padding_reduces_outliers_on_offset_field() {
    // §V-I in miniature: TS-like field (offset ~270) at a generous bound
    let ds = suite("cesm", Scale::Small, 3).unwrap();
    let ts = subsample(&ds.fields[1], 120_000);
    let run = |padding| {
        let cfg = Config { padding, eb: EbMode::Abs(1e-2), ..Config::default() };
        compress(&ts, &cfg).unwrap().1
    };
    let zero = run(PaddingPolicy::ZERO);
    let avg = run(PaddingPolicy::new(PadValue::Avg, PadGranularity::Global));
    assert!(
        avg.n_outliers < zero.n_outliers,
        "avg padding should reduce outliers: zero={} avg={}",
        zero.n_outliers,
        avg.n_outliers
    );
    // and the paper's extreme case: block-granularity average can reach
    // 100% elimination at generous bounds
    let blockavg = run(PaddingPolicy::new(PadValue::Avg, PadGranularity::Block));
    assert!(blockavg.n_outliers <= avg.n_outliers);
}

#[test]
fn sz14_and_vecsz_rate_distortion_sane() {
    let ds = suite("hurricane", Scale::Small, 4).unwrap();
    let field = subsample(&ds.fields[2], 150_000);
    for backend in [BackendChoice::Sz14, BackendChoice::Vec { width: 8 }] {
        let cfg = Config { backend, eb: EbMode::Rel(1e-3), ..Config::default() };
        let (bytes, stats) = compress(&field, &cfg).unwrap();
        let rec = decompress(&bytes, 1).unwrap();
        let d = distortion(&field.data, &rec.data);
        assert!(d.max_abs_err <= vecsz::metrics::roundtrip_tolerance(stats.eb, d.value_range));
        assert!(d.psnr_db > 40.0, "{backend:?}: psnr {:.1}", d.psnr_db);
    }
}

#[test]
fn decompression_is_deterministic_across_thread_counts() {
    let ds = suite("nyx", Scale::Small, 5).unwrap();
    let field = subsample(&ds.fields[1], 100_000);
    let cfg = Config { eb: EbMode::Rel(1e-4), threads: 3, ..Config::default() };
    let (bytes, _) = compress(&field, &cfg).unwrap();
    let r1 = decompress(&bytes, 1).unwrap();
    let r8 = decompress(&bytes, 8).unwrap();
    assert_eq!(r1.data, r8.data);
}

#[test]
fn decode_is_bit_identical_across_isas_on_every_container_version() {
    // the acceptance criterion: decoding the SAME container bytes under
    // every reachable ISA — including the forced-scalar reference path —
    // must produce bit-identical fields, for v1 (monolithic), v2 (chunked)
    // and v3 (indexed) containers and for both code kinds.
    // (force_isa flips are safe under parallel test execution precisely
    // because every backend is bit-identical on every ISA.)
    let ds = suite("cesm", Scale::Small, 7).unwrap();
    let field = subsample(&ds.fields[0], 80_000);
    for backend in [BackendChoice::Vec { width: 8 }, BackendChoice::Sz14] {
        let cfg = Config { backend, eb: EbMode::Abs(1e-3), ..Config::default() };
        let v1 = compress(&field, &cfg).unwrap().0;
        let v3 = vecsz::stream::compress_chunked(&field, &cfg, 16).unwrap().0;
        let v2_opts = vecsz::stream::StreamOptions {
            version: vecsz::format::VERSION2,
            ..vecsz::stream::StreamOptions::default()
        };
        let v2 = vecsz::stream::compress_chunked_with(&field, &cfg, 16, v2_opts).unwrap().0;
        for (tag, bytes) in [("v1", &v1), ("v2", &v2), ("v3", &v3)] {
            let baseline = decompress(bytes, 2).unwrap();
            for isa in vecsz::simd::Isa::available() {
                vecsz::simd::force_isa(Some(isa));
                let rec = decompress(bytes, 2).unwrap();
                let same = baseline
                    .data
                    .iter()
                    .zip(&rec.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(
                    same && baseline.data.len() == rec.data.len(),
                    "{tag} {backend:?}: decode diverged on {}",
                    isa.name()
                );
            }
            vecsz::simd::force_isa(None);
        }
    }
}

//! Acceptance tests for VSZ3 random access over the public API:
//! `decode_chunk(k)` is byte-identical to the corresponding slab of a full
//! decode at 1/2/7 threads, reads only the header + footer + that chunk's
//! byte range (counting-reader proof), and a corrupted or truncated footer
//! is rejected with an error — never a panic. The `Dataset` region API is
//! held to the same standard: every `Region` variant bit-identical to the
//! legacy method and the full decode (cold and warm cache), warm reads
//! decode nothing, eviction respects the byte budget, and concurrent
//! readers of a cold chunk decode it exactly once (single-flight).

// The deprecated decode_* wrappers are exercised deliberately: the matrix
// below pins them bit-identical to the Dataset reads that replace them.
#![allow(deprecated)]

use std::io::{Read, Seek, SeekFrom};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use vecsz::blocks::Dims;
use vecsz::compressor::{decompress, Config, EbMode};
use vecsz::data::Field;
use vecsz::stream::{
    compress_chunked, decompress_chunked, Dataset, DatasetOptions, Region, StreamDecompressor,
};

/// `Read + Seek` wrapper that counts the bytes actually read.
struct CountingReader {
    inner: std::io::Cursor<Vec<u8>>,
    read_bytes: Arc<AtomicU64>,
}

impl CountingReader {
    fn new(bytes: Vec<u8>) -> (Self, Arc<AtomicU64>) {
        let counter = Arc::new(AtomicU64::new(0));
        (Self { inner: std::io::Cursor::new(bytes), read_bytes: Arc::clone(&counter) }, counter)
    }
}

impl Read for CountingReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.read_bytes.fetch_add(n as u64, Ordering::Relaxed);
        Ok(n)
    }
}

impl Seek for CountingReader {
    fn seek(&mut self, pos: SeekFrom) -> std::io::Result<u64> {
        self.inner.seek(pos)
    }
}

fn walk_field(rows: usize, cols: usize, seed: u64) -> Field {
    let mut rng = vecsz::util::prng::Pcg32::seeded(seed);
    let mut x = 0.5f32;
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    Field::new("walk", Dims::d2(rows, cols), data)
}

/// Total footer size (trailing length word included).
fn footer_total(container: &[u8]) -> u64 {
    let n = container.len();
    u32::from_le_bytes(container[n - 4..].try_into().unwrap()) as u64 + 4
}

#[test]
fn acceptance_every_chunk_random_access_matches_full_decode_at_1_2_7_threads() {
    let field = walk_field(160, 64, 21);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, stats) = compress_chunked(&field, &cfg, 32).unwrap();
    assert!(stats.n_chunks >= 5, "want >= 5 chunks, got {}", stats.n_chunks);

    for threads in [1usize, 2, 7] {
        let full = decompress_chunked(&container, threads).unwrap();
        assert_eq!(full.data.len(), field.data.len());
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container[..])).unwrap();
        let mut covered = 0usize;
        for k in 0..stats.n_chunks {
            let c = dec.decode_chunk(k).unwrap();
            let lo = c.lead_offset * 64;
            let hi = lo + c.lead_extent * 64;
            assert_eq!(
                c.data,
                &full.data[lo..hi],
                "chunk {k} differs from the full decode at {threads} threads"
            );
            covered += c.lead_extent;
        }
        assert_eq!(covered, 160, "chunks must tile the field");
        // multi-chunk range decode agrees too
        let range = dec.decode_range(1..stats.n_chunks, threads).unwrap();
        assert_eq!(range, &full.data[32 * 64..]);
    }
}

#[test]
fn acceptance_decode_chunk_reads_only_header_footer_and_that_frame() {
    let field = walk_field(128, 32, 23);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, stats) = compress_chunked(&field, &cfg, 16).unwrap();
    assert!(stats.n_chunks >= 8);
    let total = container.len() as u64;
    let footer = footer_total(&container);

    let (reader, counter) = CountingReader::new(container.clone());
    let mut dec = StreamDecompressor::new(reader).unwrap();
    let after_header = counter.load(Ordering::Relaxed);

    // loading the index reads the length word + the footer (the 4 length
    // bytes land in both the first probe and the footer slice, so allow
    // them twice)
    dec.load_index().unwrap();
    let after_index = counter.load(Ordering::Relaxed);
    assert!(
        after_index - after_header <= footer + 4,
        "index load read {} bytes, footer is only {footer}",
        after_index - after_header
    );

    // decoding chunk k reads exactly its frame
    let k = stats.n_chunks / 2;
    let frame_len = {
        let idx = dec.load_index().unwrap();
        idx.entries[k].frame_len
    };
    let before = counter.load(Ordering::Relaxed);
    let chunk = dec.decode_chunk(k).unwrap();
    let after = counter.load(Ordering::Relaxed);
    assert_eq!(after - before, frame_len, "decode_chunk read more than the chunk's byte range");
    assert_eq!(chunk.index, k as u64);

    // and the total is far below the container size (nothing else read)
    assert!(
        after < total / 2,
        "random access read {after} of {total} bytes — that is not partial decode"
    );
}

#[test]
fn footer_corruption_and_truncation_never_panic_via_public_api() {
    let field = walk_field(96, 32, 29);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, _) = compress_chunked(&field, &cfg, 16).unwrap();
    let ft = footer_total(&container) as usize;
    let start = container.len() - ft;

    for at in start..container.len() {
        let mut bad = container.clone();
        bad[at] ^= 0x55;
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bad[..])).unwrap();
        assert!(dec.load_index().is_err(), "footer flip at {at} accepted by the index loader");
        // the in-memory full decoder cross-checks the footer as well
        assert!(decompress(&bad, 2).is_err(), "footer flip at {at} accepted by decompress");
    }
    for cut in [container.len() - 1, container.len() - 5, start + 1, start] {
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container[..cut])).unwrap();
        assert!(dec.load_index().is_err(), "footer cut at {cut} accepted");
        assert!(decompress(&container[..cut], 1).is_err());
    }
    // the pristine container still works after all that
    assert!(decompress(&container, 2).is_ok());
}

fn open_dataset(container: &[u8], threads: usize) -> Dataset<std::io::Cursor<Vec<u8>>> {
    let opts = DatasetOptions { threads, ..DatasetOptions::default() };
    Dataset::open_with(std::io::Cursor::new(container.to_vec()), opts).unwrap()
}

#[test]
fn acceptance_region_matrix_bit_identical_to_legacy_cold_and_warm() {
    let field = walk_field(160, 64, 31);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, stats) = compress_chunked(&field, &cfg, 32).unwrap();
    let n = stats.n_chunks;
    assert!(n >= 5);
    let full = decompress_chunked(&container, 1).unwrap();

    for threads in [1usize, 2, 7] {
        let ds = open_dataset(&container, threads);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container[..])).unwrap();
        // two passes over the same handle: pass 0 fills the cache (cold),
        // pass 1 reads resident slabs (warm) — results must not change
        for pass in 0..2 {
            let tag = if pass == 0 { "cold" } else { "warm" };
            for k in 0..n {
                let via_ds = ds.read(Region::Chunk(k)).unwrap();
                let legacy = dec.decode_chunk(k).unwrap();
                assert_eq!(via_ds, legacy.data, "Chunk({k}) {tag} {threads}T");
                let lo = legacy.lead_offset * 64;
                let hi = lo + legacy.lead_extent * 64;
                assert_eq!(via_ds, &full.data[lo..hi], "Chunk({k}) vs slab {tag} {threads}T");
            }
            assert_eq!(
                ds.read(Region::Chunks(1..n)).unwrap(),
                dec.decode_range(1..n, threads).unwrap(),
                "Chunks {tag} {threads}T"
            );
            let rows = ds.read(Region::Rows(13..131)).unwrap();
            assert_eq!(rows, dec.decode_rows(13..131, threads).unwrap(), "Rows {tag} {threads}T");
            assert_eq!(rows, &full.data[13 * 64..131 * 64], "Rows vs slab {tag} {threads}T");
            assert_eq!(
                ds.read(Region::Dim { dim: 1, range: 5..40 }).unwrap(),
                dec.decode_cols(5..40, threads).unwrap(),
                "Dim1 {tag} {threads}T"
            );
            assert_eq!(
                ds.read(Region::Dim { dim: 0, range: 40..96 }).unwrap(),
                dec.decode_rows(40..96, threads).unwrap(),
                "Dim0 {tag} {threads}T"
            );
            assert_eq!(ds.read(Region::All).unwrap(), full.data, "All {tag} {threads}T");
        }
        let snap = ds.cache_stats();
        assert!(snap.hits > 0, "warm pass must hit the cache ({threads}T)");
        assert_eq!(snap.evictions, 0, "default budget must hold the whole field ({threads}T)");
    }
}

#[test]
fn acceptance_region_matrix_3d_dim_reads_match_legacy() {
    let mut rng = vecsz::util::prng::Pcg32::seeded(41);
    let mut x = 0.0f32;
    let data: Vec<f32> = (0..24 * 10 * 12)
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    let field = Field::new("walk3", Dims::d3(24, 10, 12), data);
    let cfg = Config { eb: EbMode::Abs(1e-3), block_size: 4, ..Config::default() };
    let (container, stats) = compress_chunked(&field, &cfg, 4).unwrap();
    assert!(stats.n_chunks >= 4);

    for threads in [1usize, 3] {
        let ds = open_dataset(&container, threads);
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container[..])).unwrap();
        for _pass in 0..2 {
            assert_eq!(
                ds.read(Region::Dim { dim: 1, range: 3..8 }).unwrap(),
                dec.decode_dim(1, 3..8, threads).unwrap()
            );
            assert_eq!(
                ds.read(Region::Dim { dim: 2, range: 2..9 }).unwrap(),
                dec.decode_cols(2..9, threads).unwrap()
            );
            assert_eq!(
                ds.read(Region::Rows(5..17)).unwrap(),
                dec.decode_rows(5..17, threads).unwrap()
            );
        }
    }
}

#[test]
fn warm_cache_reads_perform_zero_chunk_decodes() {
    let field = walk_field(160, 64, 37);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, _) = compress_chunked(&field, &cfg, 32).unwrap();
    let full = decompress_chunked(&container, 1).unwrap();

    let ds = open_dataset(&container, 2);
    // rows 8..72 cover chunks 0..3 (span 32)
    let first = ds.read(Region::Rows(8..72)).unwrap();
    assert_eq!(first, &full.data[8 * 64..72 * 64]);
    let decodes_after_fill = ds.decode_count();
    assert_eq!(decodes_after_fill, 3, "rows 8..72 span exactly three chunks");

    // identical and nested re-reads are served entirely from the cache:
    // the decode counter must not move
    assert_eq!(ds.read(Region::Rows(8..72)).unwrap(), first);
    assert_eq!(ds.read(Region::Rows(16..40)).unwrap(), &full.data[16 * 64..40 * 64]);
    assert_eq!(ds.read(Region::Chunk(1)).unwrap(), &full.data[32 * 64..64 * 64]);
    assert_eq!(ds.read(Region::Chunks(0..3)).unwrap(), &full.data[..96 * 64]);
    assert_eq!(ds.decode_count(), decodes_after_fill, "warm reads must decode nothing");
    let snap = ds.cache_stats();
    assert_eq!(snap.misses, 3);
    assert!(snap.hits >= 6, "got {} hits", snap.hits);
}

#[test]
fn eviction_under_pressure_bounds_residency_and_stays_correct() {
    let field = walk_field(160, 64, 43);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, stats) = compress_chunked(&field, &cfg, 32).unwrap();
    assert_eq!(stats.n_chunks, 5);
    let full = decompress_chunked(&container, 1).unwrap();

    // one slab is 32 rows * 64 cols * 4 B = 8 KiB; budget fits two and a half
    let budget = 20_480u64;
    let opts = DatasetOptions { threads: 2, cache_bytes: budget };
    let ds = Dataset::open_with(std::io::Cursor::new(container.clone()), opts).unwrap();
    for round in 0..3 {
        assert_eq!(ds.read(Region::All).unwrap(), full.data, "round {round}");
        let snap = ds.cache_stats();
        assert!(
            snap.resident_bytes <= budget,
            "round {round}: resident {} exceeds budget {budget}",
            snap.resident_bytes
        );
    }
    let snap = ds.cache_stats();
    assert!(snap.evictions > 0, "a 2.5-slab budget over 5 slabs must evict");
    assert!(snap.hits > 0, "surviving residents must serve later rounds");
    // narrow reads under pressure stay correct as well
    assert_eq!(ds.read(Region::Rows(150..160)).unwrap(), &full.data[150 * 64..]);
}

#[test]
fn concurrent_readers_of_a_cold_chunk_decode_it_exactly_once() {
    let field = walk_field(96, 32, 47);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, _) = compress_chunked(&field, &cfg, 16).unwrap();
    let full = decompress_chunked(&container, 1).unwrap();
    let expect = &full.data[16 * 32..32 * 32];

    const READERS: usize = 8;
    let ds = open_dataset(&container, 1);
    let barrier = std::sync::Barrier::new(READERS);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..READERS)
            .map(|_| {
                let (ds, barrier) = (&ds, &barrier);
                s.spawn(move || {
                    barrier.wait();
                    ds.read(Region::Chunk(1)).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), expect);
        }
    });
    // single-flight: one reader claimed the decode, everyone else was
    // served that same slab (in flight or resident)
    assert_eq!(ds.decode_count(), 1, "the cold chunk must decode exactly once");
    let snap = ds.cache_stats();
    assert_eq!(snap.misses, 1);
    assert_eq!(snap.hits, (READERS - 1) as u64);
}

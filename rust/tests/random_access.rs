//! Acceptance tests for VSZ3 random access over the public API:
//! `decode_chunk(k)` is byte-identical to the corresponding slab of a full
//! decode at 1/2/7 threads, reads only the header + footer + that chunk's
//! byte range (counting-reader proof), and a corrupted or truncated footer
//! is rejected with an error — never a panic.

use std::io::{Read, Seek, SeekFrom};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use vecsz::blocks::Dims;
use vecsz::compressor::{decompress, Config, EbMode};
use vecsz::data::Field;
use vecsz::stream::{compress_chunked, decompress_chunked, StreamDecompressor};

/// `Read + Seek` wrapper that counts the bytes actually read.
struct CountingReader {
    inner: std::io::Cursor<Vec<u8>>,
    read_bytes: Arc<AtomicU64>,
}

impl CountingReader {
    fn new(bytes: Vec<u8>) -> (Self, Arc<AtomicU64>) {
        let counter = Arc::new(AtomicU64::new(0));
        (Self { inner: std::io::Cursor::new(bytes), read_bytes: Arc::clone(&counter) }, counter)
    }
}

impl Read for CountingReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.read_bytes.fetch_add(n as u64, Ordering::Relaxed);
        Ok(n)
    }
}

impl Seek for CountingReader {
    fn seek(&mut self, pos: SeekFrom) -> std::io::Result<u64> {
        self.inner.seek(pos)
    }
}

fn walk_field(rows: usize, cols: usize, seed: u64) -> Field {
    let mut rng = vecsz::util::prng::Pcg32::seeded(seed);
    let mut x = 0.5f32;
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| {
            x += (rng.next_f32() - 0.5) * 0.1;
            x
        })
        .collect();
    Field::new("walk", Dims::d2(rows, cols), data)
}

/// Total footer size (trailing length word included).
fn footer_total(container: &[u8]) -> u64 {
    let n = container.len();
    u32::from_le_bytes(container[n - 4..].try_into().unwrap()) as u64 + 4
}

#[test]
fn acceptance_every_chunk_random_access_matches_full_decode_at_1_2_7_threads() {
    let field = walk_field(160, 64, 21);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, stats) = compress_chunked(&field, &cfg, 32).unwrap();
    assert!(stats.n_chunks >= 5, "want >= 5 chunks, got {}", stats.n_chunks);

    for threads in [1usize, 2, 7] {
        let full = decompress_chunked(&container, threads).unwrap();
        assert_eq!(full.data.len(), field.data.len());
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container[..])).unwrap();
        let mut covered = 0usize;
        for k in 0..stats.n_chunks {
            let c = dec.decode_chunk(k).unwrap();
            let lo = c.lead_offset * 64;
            let hi = lo + c.lead_extent * 64;
            assert_eq!(
                c.data,
                &full.data[lo..hi],
                "chunk {k} differs from the full decode at {threads} threads"
            );
            covered += c.lead_extent;
        }
        assert_eq!(covered, 160, "chunks must tile the field");
        // multi-chunk range decode agrees too
        let range = dec.decode_range(1..stats.n_chunks, threads).unwrap();
        assert_eq!(range, &full.data[32 * 64..]);
    }
}

#[test]
fn acceptance_decode_chunk_reads_only_header_footer_and_that_frame() {
    let field = walk_field(128, 32, 23);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, stats) = compress_chunked(&field, &cfg, 16).unwrap();
    assert!(stats.n_chunks >= 8);
    let total = container.len() as u64;
    let footer = footer_total(&container);

    let (reader, counter) = CountingReader::new(container.clone());
    let mut dec = StreamDecompressor::new(reader).unwrap();
    let after_header = counter.load(Ordering::Relaxed);

    // loading the index reads the length word + the footer (the 4 length
    // bytes land in both the first probe and the footer slice, so allow
    // them twice)
    dec.load_index().unwrap();
    let after_index = counter.load(Ordering::Relaxed);
    assert!(
        after_index - after_header <= footer + 4,
        "index load read {} bytes, footer is only {footer}",
        after_index - after_header
    );

    // decoding chunk k reads exactly its frame
    let k = stats.n_chunks / 2;
    let frame_len = {
        let idx = dec.load_index().unwrap();
        idx.entries[k].frame_len
    };
    let before = counter.load(Ordering::Relaxed);
    let chunk = dec.decode_chunk(k).unwrap();
    let after = counter.load(Ordering::Relaxed);
    assert_eq!(after - before, frame_len, "decode_chunk read more than the chunk's byte range");
    assert_eq!(chunk.index, k as u64);

    // and the total is far below the container size (nothing else read)
    assert!(
        after < total / 2,
        "random access read {after} of {total} bytes — that is not partial decode"
    );
}

#[test]
fn footer_corruption_and_truncation_never_panic_via_public_api() {
    let field = walk_field(96, 32, 29);
    let cfg = Config { eb: EbMode::Abs(1e-3), ..Config::default() };
    let (container, _) = compress_chunked(&field, &cfg, 16).unwrap();
    let ft = footer_total(&container) as usize;
    let start = container.len() - ft;

    for at in start..container.len() {
        let mut bad = container.clone();
        bad[at] ^= 0x55;
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&bad[..])).unwrap();
        assert!(dec.load_index().is_err(), "footer flip at {at} accepted by the index loader");
        // the in-memory full decoder cross-checks the footer as well
        assert!(decompress(&bad, 2).is_err(), "footer flip at {at} accepted by decompress");
    }
    for cut in [container.len() - 1, container.len() - 5, start + 1, start] {
        let mut dec = StreamDecompressor::new(std::io::Cursor::new(&container[..cut])).unwrap();
        assert!(dec.load_index().is_err(), "footer cut at {cut} accepted");
        assert!(decompress(&container[..cut], 1).is_err());
    }
    // the pristine container still works after all that
    assert!(decompress(&container, 2).is_ok());
}

//! Autotuning across simulation time-steps (§V-F of the paper).
//!
//!     cargo run --release --example autotune_timeseries
//!
//! Shows (a) the exhaustive (block size × lane width) landscape for one
//! field, (b) how the sampling autotuner finds a near-peak configuration at
//! a fraction of the cost, and (c) the paper's amortization argument: the
//! winning configuration is stable across time-steps, so tuning once and
//! narrowing to the top-2 configs covers almost every step.

use vecsz::autotune::{autotune, exhaustive_full, top_k_stability, TuneSettings};
use vecsz::data::{suite, Scale};
use vecsz::padding::PaddingPolicy;

fn main() {
    let ds = suite("hurricane", Scale::Small, 11).unwrap();
    let field = vecsz::figures::subsample(&ds.fields[0], 1 << 20);
    let eb = 1e-3 * vecsz::metrics::value_range(&field.data);
    println!("field {} ({:.1} MB), eb {:.3e}\n", field.name, field.size_mb(), eb);

    // (a) ground truth: full-field bandwidth of every configuration
    println!("exhaustive landscape (full-field P&Q bandwidth):");
    let full = exhaustive_full(&field, eb, 512, PaddingPolicy::ZERO, &[8, 16], 1);
    let peak = full.iter().map(|p| p.mb_per_s).fold(f64::MIN, f64::max);
    for p in &full {
        let bar = "#".repeat((40.0 * p.mb_per_s / peak) as usize);
        println!(
            "  bs={:<3} w={:<2} {:>8.0} MB/s {}",
            p.config.block_size, p.config.width, p.mb_per_s, bar
        );
    }

    // (b) the sampling autotuner at increasing effort
    println!("\nautotuner (sample% x iterations -> % of peak, tuning cost):");
    for (sp, it) in [(1.0, 1), (5.0, 2), (10.0, 4), (20.0, 8)] {
        let r = autotune(
            &field,
            eb,
            512,
            PaddingPolicy::ZERO,
            &[8, 16],
            TuneSettings { sample_pct: sp, iterations: it, seed: 5 },
        );
        let chosen = full
            .iter()
            .find(|p| p.config == r.best)
            .map(|p| p.mb_per_s)
            .unwrap_or(0.0);
        println!(
            "  sample {:>4.0}% iters {:<2} -> bs{:<3} w{:<2} = {:>5.1}% of peak  ({:.0} ms tuning)",
            sp,
            it,
            r.best.block_size,
            r.best.width,
            100.0 * chosen / peak,
            r.tune_seconds * 1e3
        );
    }

    // (c) stability across "time-steps" (fresh sampling per step)
    println!("\nstability across 16 time-steps (fresh random sample each):");
    let runs: Vec<_> = (0..16)
        .map(|s| {
            autotune(
                &field,
                eb,
                512,
                PaddingPolicy::ZERO,
                &[8, 16],
                TuneSettings { sample_pct: 5.0, iterations: 2, seed: 100 + s },
            )
        })
        .collect();
    println!("  top-1 coverage: {:>5.1}%", 100.0 * top_k_stability(&runs, 1));
    println!("  top-2 coverage: {:>5.1}%  (paper: ~80% for Hurricane)", 100.0 * top_k_stability(&runs, 2));
}

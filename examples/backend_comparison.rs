//! Backend comparison: the paper's Fig 3 in miniature.
//!
//!     cargo run --release --example backend_comparison
//!
//! Benchmarks every P&Q backend (SZ-1.4, pSZ, vecSZ at widths 8/16) on
//! identical block batches for 1D/2D/3D shapes.

use vecsz::bench::{bench, BenchOpts};
use vecsz::blocks::BlockShape;
use vecsz::padding::{PadGranularity, PadScalars, PadValue, PaddingPolicy};
use vecsz::quant::psz::PszBackend;
use vecsz::quant::sz14::Sz14Backend;
use vecsz::quant::vectorized::VecBackend;
use vecsz::quant::{DqConfig, PqBackend};
use vecsz::util::prng::Pcg32;

fn main() {
    let opts = BenchOpts::from_env();
    let mut rng = Pcg32::seeded(1);
    for (ndim, bs) in [(1usize, 256usize), (2, 16), (3, 8)] {
        let shape = BlockShape::new(ndim, bs);
        let elems = shape.elems();
        let nbb = (1 << 22) / elems;
        let mut blocks = vec![0.0f32; nbb * elems];
        let mut x = 0.0f32;
        for v in blocks.iter_mut() {
            x += (rng.next_f32() - 0.5) * 0.1;
            *v = x;
        }
        let pads = PadScalars {
            policy: PaddingPolicy::new(PadValue::Zero, PadGranularity::Global),
            scalars: vec![0.0],
            ndim,
        };
        let cfg = DqConfig::new(1e-3, 512, shape);
        let mut codes = vec![0u16; blocks.len()];
        let mut outv = vec![0.0f32; blocks.len()];
        println!("-- {ndim}D, block size {bs}, {} blocks --", nbb);
        for be in [
            &Sz14Backend as &dyn PqBackend,
            &PszBackend,
            &VecBackend::new(8),
            &VecBackend::new(16),
        ] {
            let s = bench(&format!("{ndim}D [{}]", be.name()), blocks.len() * 4, opts, || {
                be.run(&cfg, &blocks, 0, &pads, &mut codes, &mut outv);
                std::hint::black_box(&codes);
            });
            println!("{}", s.row());
        }
    }
}

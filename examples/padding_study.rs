//! Alternative block padding (§IV / §V-I): why zero padding hurts offset
//! fields and how statistical padding repairs the block borders.
//!
//!     cargo run --release --example padding_study
//!
//! Compresses the CESM-like surface-temperature field (values ~230-310, the
//! Fig 2 situation) under every padding policy and reports outliers,
//! compression ratio and rate-distortion, then sweeps the error bound to
//! show the paper's observation that border outliers dominate at large eb.

use vecsz::compressor::{compress, decompress, BackendChoice, Config, EbMode};
use vecsz::data::{suite, Scale};
use vecsz::metrics::distortion;
use vecsz::padding::{study_policies, PaddingPolicy};

fn main() -> vecsz::Result<()> {
    let ds = suite("cesm", Scale::Small, 9).unwrap();
    let ts = vecsz::figures::subsample(&ds.fields[1], 1 << 19); // TS field
    let mean = ts.data.iter().map(|&x| x as f64).sum::<f64>() / ts.data.len() as f64;
    println!("field {} — mean {:.1} (non-zero-centred: the Fig 2 case)\n", ts.name, mean);

    let eb = 0.05; // generous bound: interior predicts perfectly, borders dominate
    println!("policy grid at eb={eb} (outliers / reduction vs zero / CR / PSNR):");
    let mut zero_out = None;
    for policy in study_policies() {
        let cfg = Config {
            eb: EbMode::Abs(eb),
            padding: policy,
            backend: BackendChoice::Vec { width: 16 },
            ..Config::default()
        };
        let (bytes, stats) = compress(&ts, &cfg)?;
        let rec = decompress(&bytes, 1)?;
        let d = distortion(&ts.data, &rec.data);
        let z = *zero_out.get_or_insert(stats.n_outliers);
        let red = if z == 0 { 0.0 } else { 100.0 * (z - stats.n_outliers.min(z)) as f64 / z as f64 };
        println!(
            "  {:<11} {:>8} outliers  {:>6.1}% fewer  CR {:>6.2}x  PSNR {:>6.1} dB",
            policy.name(),
            stats.n_outliers,
            red,
            stats.size.ratio(),
            d.psnr_db
        );
    }

    println!("\nerror-bound sweep (zero vs avg-global, % of values that are outliers):");
    println!("{:>10} {:>12} {:>12} {:>12}", "eb", "zero", "avg-global", "reduction");
    for eb in [0.001, 0.005, 0.02, 0.05, 0.2] {
        let run = |padding: PaddingPolicy| {
            let cfg = Config {
                eb: EbMode::Abs(eb),
                padding,
                backend: BackendChoice::Vec { width: 16 },
                ..Config::default()
            };
            compress(&ts, &cfg).unwrap().1
        };
        let z = run(PaddingPolicy::ZERO);
        let a = run(PaddingPolicy::parse("avg-global").unwrap());
        let red = if z.n_outliers == 0 {
            0.0
        } else {
            100.0 * (z.n_outliers - a.n_outliers.min(z.n_outliers)) as f64 / z.n_outliers as f64
        };
        println!(
            "{:>10} {:>11.3}% {:>11.3}% {:>11.1}%",
            eb,
            z.outlier_pct(),
            a.outlier_pct(),
            red
        );
    }
    println!("\n(paper: avg padding removes up to 100% of outliers at large eb,");
    println!(" improving rate-distortion by up to 32% on Hurricane / 18.9% on CESM)");
    Ok(())
}

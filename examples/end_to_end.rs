//! END-TO-END DRIVER — the full system on a real small workload.
//!
//!     cargo run --release --example end_to_end
//!
//! Exercises every layer in one run and proves they compose:
//!   L1/L2  AOT XLA/Pallas artifact executed via PJRT (when artifacts/ is
//!          built) cross-checked bit-exactly against the native backend;
//!   L3     the streaming coordinator compressing a 24-time-step synthetic
//!          climate simulation with autotuning, backpressure and per-stage
//!          metrics; then decompressing and verifying every step.
//!
//! Reports the paper's headline metric (prediction/quantization bandwidth)
//! plus compression ratio and PSNR per step. Recorded in EXPERIMENTS.md.

use std::path::Path;

use vecsz::blocks::BlockShape;
use vecsz::compressor::{decompress, Config, EbMode};
use vecsz::coordinator::pipeline::{run_stream, PipelineConfig};
use vecsz::data::{suite, Scale};
use vecsz::metrics::distortion;
use vecsz::padding::{PadGranularity, PadScalars, PadValue, PaddingPolicy};
use vecsz::quant::psz::PszBackend;
use vecsz::quant::{DqConfig, PqBackend};
use vecsz::util::prng::Pcg32;

const STEPS: usize = 24;

fn main() -> vecsz::Result<()> {
    println!("== vecSZ end-to-end driver ==\n");

    // ---- Layer 1/2: PJRT artifact cross-check --------------------------
    if Path::new("artifacts/manifest.json").exists() {
        let rt = vecsz::runtime::PjrtRuntime::new(Path::new("artifacts"))?;
        println!("[L1/L2] PJRT platform: {}", rt.platform());
        let shape = BlockShape::new(2, 16);
        let cfg = DqConfig::new(1e-3, 512, shape);
        let pjrt = vecsz::runtime::PjrtBackend::new(&rt, 2, 16, 8)?;
        let (blocks, pads) = sample_blocks(shape, 64);
        let elems = shape.elems();
        let mut cn = vec![0u16; blocks.len()];
        let mut vn = vec![0.0f32; blocks.len()];
        PszBackend.run(&cfg, &blocks, 0, &pads, &mut cn, &mut vn);
        let mut cp = vec![0u16; blocks.len()];
        let mut vp = vec![0.0f32; blocks.len()];
        pjrt.run(&cfg, &blocks, 0, &pads, &mut cp, &mut vp);
        assert_eq!(cn, cp, "PJRT and native quant codes must be bit-identical");
        assert_eq!(vn, vp);
        println!(
            "[L1/L2] AOT artifact ({}) == native backend on {} blocks x {} elems ✔\n",
            pjrt.name(),
            blocks.len() / elems,
            elems
        );
    } else {
        println!("[L1/L2] artifacts/ not built (run `make artifacts`); skipping PJRT check\n");
    }

    // ---- Layer 3: streaming 24-step simulation -------------------------
    println!("[L3] streaming {STEPS}-step CESM-like simulation through the coordinator");
    let pcfg = PipelineConfig {
        base: Config {
            eb: EbMode::Rel(1e-4),
            padding: PaddingPolicy::new(PadValue::Avg, PadGranularity::Global),
            threads: 1,
            ..Config::default()
        },
        retune_every: 12,
        widths: [8, 16],
        queue_depth: 2,
        ..PipelineConfig::default()
    };
    let mut blobs: Vec<Vec<u8>> = Vec::new();
    let report = {
        let sink_blobs: *mut Vec<Vec<u8>> = &mut blobs;
        run_stream(
            |i| {
                if i >= STEPS {
                    return None;
                }
                // evolved field per step: seed drift models simulation time
                suite("cesm", Scale::Small, 4242 + i as u64).map(|ds| {
                    let mut f = ds.fields.into_iter().next().unwrap();
                    f = vecsz::figures::subsample(&f, 1 << 19);
                    f.name = format!("CLDHGH_t{i:02}");
                    f
                })
            },
            pcfg,
            |_, bytes| {
                // single-threaded sink; raw pointer keeps the closure Fn-only
                unsafe { (*sink_blobs).push(bytes) };
                Ok(())
            },
        )?
    };

    println!("{:<14} {:>8} {:>10} {:>9} {:>8}  {}", "step", "CR", "P&Q MB/s", "outl %", "stall ms", "tuned");
    for s in &report.steps {
        println!(
            "{:<14} {:>7.2}x {:>10.0} {:>8.3}% {:>8.1}  {}",
            s.field_name,
            s.stats.size.ratio(),
            s.stats.pq_bandwidth_mbs(),
            s.stats.outlier_pct(),
            s.stall_seconds * 1e3,
            s.tuned.map(|t| format!("bs{} w{}", t.block_size, t.width)).unwrap_or_default()
        );
    }

    // ---- verify every step decompresses within bound -------------------
    let mut worst_psnr = f64::INFINITY;
    for (i, b) in blobs.iter().enumerate() {
        let rec = decompress(b, 1)?;
        let orig = {
            let ds = suite("cesm", Scale::Small, 4242 + i as u64).unwrap();
            vecsz::figures::subsample(&ds.fields[0], 1 << 19)
        };
        let d = distortion(&orig.data, &rec.data);
        let eb = report.steps[i].stats.eb;
        assert!(
            d.max_abs_err <= vecsz::metrics::roundtrip_tolerance(eb, d.value_range),
            "step {i}: bound violated"
        );
        worst_psnr = worst_psnr.min(d.psnr_db);
    }

    println!("\n== summary ==");
    println!("steps                 : {}", report.steps.len());
    println!("wall time             : {:.2} s", report.total_seconds);
    println!("overall ratio         : {:.2}x", report.overall_ratio());
    println!("mean P&Q bandwidth    : {:.0} MB/s (paper headline metric)", report.mean_pq_mbs());
    println!("autotune overhead     : {:.2}% of wall", report.tune_overhead_pct());
    println!("worst-step PSNR       : {:.1} dB", worst_psnr);
    println!("error bound           : verified on all {} steps ✔", report.steps.len());
    Ok(())
}

fn sample_blocks(shape: BlockShape, nb: usize) -> (Vec<f32>, PadScalars) {
    let elems = shape.elems();
    let mut rng = Pcg32::seeded(7);
    let mut blocks = vec![0.0f32; nb * elems];
    let mut x = 0.0f32;
    for v in blocks.iter_mut() {
        x += (rng.next_f32() - 0.5) * 0.1;
        *v = x;
    }
    let scalars = (0..nb)
        .map(|b| {
            let s = &blocks[b * elems..(b + 1) * elems];
            s.iter().sum::<f32>() / elems as f32
        })
        .collect();
    (
        blocks,
        PadScalars {
            policy: PaddingPolicy::new(PadValue::Avg, PadGranularity::Block),
            scalars,
            ndim: shape.ndim,
        },
    )
}

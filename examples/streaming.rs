//! Streaming chunked compression: out-of-core fields in bounded memory.
//!
//!     cargo run --release --example streaming
//!
//! Demonstrates the chunked container engine end to end:
//!   1. a producer streams a large 2D field slab-by-slab into
//!      `StreamCompressor` — the whole field never exists in RAM on the
//!      compress side;
//!   2. the container decodes chunk-parallel through the thread pool and is
//!      verified to be byte-identical to the serial decode;
//!   3. `StreamDecompressor` walks the chunks incrementally, verifying the
//!      error bound slab by slab — the decompress side is bounded too;
//!   4. the v3 index footer enables random access: one chunk (or row
//!      range) decodes without touching the rest of the container.

use vecsz::blocks::Dims;
use vecsz::compressor::{Config, EbMode};
use vecsz::stream::{decompress_chunked, StreamCompressor, StreamDecompressor};
use vecsz::util::prng::Pcg32;

const ROWS: usize = 2048;
const COLS: usize = 1024;
const EB: f64 = 1e-3;

/// Deterministic row generator — stands in for a simulation/file producer.
fn make_row(rng: &mut Pcg32, carry: &mut f32, cols: usize) -> Vec<f32> {
    (0..cols)
        .map(|_| {
            *carry += (rng.next_f32() - 0.5) * 0.1;
            *carry
        })
        .collect()
}

fn main() -> vecsz::Result<()> {
    let dims = Dims::d2(ROWS, COLS);
    let cfg = Config { eb: EbMode::Abs(EB), threads: 4, ..Config::default() };

    // -- 1. stream the field in, one row at a time ------------------------
    let mut sc = StreamCompressor::new(Vec::new(), dims, &cfg, 64)?;
    let mut rng = Pcg32::seeded(2024);
    let mut carry = 0.0f32;
    for _ in 0..ROWS {
        sc.push(&make_row(&mut rng, &mut carry, COLS))?;
    }
    let (container, stats) = sc.finish()?;
    println!(
        "streamed {} rows into {} chunks: {:.1} MB -> {:.1} MB (CR {:.2}x, {} outliers)",
        ROWS,
        stats.n_chunks,
        stats.raw_bytes as f64 / 1e6,
        stats.compressed_bytes as f64 / 1e6,
        stats.ratio(),
        stats.n_outliers,
    );

    // -- 2. chunk-parallel decode == serial decode ------------------------
    let serial = decompress_chunked(&container, 1)?;
    let parallel = decompress_chunked(&container, 4)?;
    assert_eq!(serial.data, parallel.data, "thread count must not change output");
    println!("chunk-parallel decode (4 threads) is byte-identical to serial ✔");

    // -- 3. incremental decode, verifying the bound slab by slab ----------
    let mut dec = StreamDecompressor::new(&container[..])?;
    let mut rng = Pcg32::seeded(2024);
    let mut carry = 0.0f32;
    let mut max_err = 0.0f64;
    while let Some(chunk) = dec.next_chunk()? {
        for row in chunk.data.chunks(COLS) {
            let orig = make_row(&mut rng, &mut carry, COLS);
            for (o, r) in orig.iter().zip(row) {
                max_err = max_err.max((*o as f64 - *r as f64).abs());
            }
        }
    }
    assert!(max_err <= EB + 1e-6);
    println!("incremental decode verified: max |err| {max_err:.3e} <= eb {EB:.1e} ✔");

    // -- 4. random access through the v3 index footer ---------------------
    let mut ra = StreamDecompressor::new(std::io::Cursor::new(&container[..]))?;
    let n_chunks = ra.load_index()?.n_chunks();
    let mid = n_chunks / 2;
    let chunk = ra.decode_chunk(mid)?;
    assert_eq!(
        chunk.data,
        serial.data[chunk.lead_offset * COLS..(chunk.lead_offset + chunk.lead_extent) * COLS]
    );
    let rows = ra.decode_rows(100..164, 4)?;
    assert_eq!(rows, serial.data[100 * COLS..164 * COLS]);
    println!(
        "random access: chunk {mid}/{n_chunks} and rows 100..164 decoded \
         without touching the rest of the container ✔"
    );

    // -- 5. column ranges: every chunk overlaps, so all chunks decode
    //       chunk-parallel and the extent is gathered per slab -------------
    let (lo, hi) = (COLS / 4, COLS / 2);
    let cols = ra.decode_cols(lo..hi, 4)?;
    let expect: Vec<f32> = serial
        .data
        .chunks(COLS)
        .flat_map(|row| row[lo..hi].to_vec())
        .collect();
    assert_eq!(cols, expect);
    println!("column range {lo}..{hi} gathered from all chunks ✔");
    Ok(())
}

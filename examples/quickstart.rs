//! Quickstart: compress one field, decompress it, verify the error bound.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the whole public API surface in ~40 lines: synthetic data,
//! configuration, compression stats, reconstruction quality.

use vecsz::compressor::{compress, decompress, BackendChoice, Config, EbMode};
use vecsz::data::{suite, Scale};
use vecsz::metrics::distortion;
use vecsz::padding::{PadGranularity, PadValue, PaddingPolicy};

fn main() -> vecsz::Result<()> {
    // 1. get a field (CESM-like 2D climate data; use your own Vec<f32> +
    //    Dims in real code — see vecsz::data::io for raw-file loading)
    let dataset = suite("cesm", Scale::Small, 42).unwrap();
    let field = &dataset.fields[0];
    println!("field {} ({:.1} MB)", field.name, field.size_mb());

    // 2. configure: absolute error bound, vectorized backend (16 lanes),
    //    average-value padding at global granularity (the paper's Fig 10
    //    configuration)
    let cfg = Config {
        eb: EbMode::Abs(1e-4),
        backend: BackendChoice::Vec { width: 16 },
        padding: PaddingPolicy::new(PadValue::Avg, PadGranularity::Global),
        ..Config::default()
    };

    // 3. compress
    let (bytes, stats) = compress(field, &cfg)?;
    println!(
        "compressed: {:.2}x ratio, {:.2} bits/value, P&Q stage at {:.0} MB/s, {:.3}% outliers",
        stats.size.ratio(),
        stats.size.bit_rate(),
        stats.pq_bandwidth_mbs(),
        stats.outlier_pct()
    );

    // 4. decompress + verify
    let restored = decompress(&bytes, 1)?;
    let d = distortion(&field.data, &restored.data);
    println!("max |err| = {:.3e} (bound {:.3e}), PSNR {:.1} dB", d.max_abs_err, stats.eb, d.psnr_db);
    assert!(d.max_abs_err <= vecsz::metrics::roundtrip_tolerance(stats.eb, d.value_range));
    println!("error bound verified ✔");
    Ok(())
}

"""Numeric verification of vecsz test thresholds, ported bit-faithfully
(f32 semantics via numpy) from the Rust sources.

Checks:
  1. real_suite_field_compresses_well: CLDHGH 128x256 slab, eb=1e-3,
     bs=16, zero padding -> compression ratio must be > 4.0
  2. avg_padding_reduces_outliers_on_offset_field: TS 66x1800 slab,
     eb=1e-2: outliers(avg-global) < outliers(zero), blockavg <= avg
  3. cesm_cloud_fraction_in_unit_interval: flat (0/1) fraction > 2%
"""
import numpy as np
import heapq

f32 = np.float32
U64 = np.uint64
MASK = U64(0xFFFFFFFFFFFFFFFF)

def mix64(x):
    x = (x + U64(0x9E3779B97F4A7C15)) & MASK
    x = ((x ^ (x >> U64(30))) * U64(0xBF58476D1CE4E5B9)) & MASK
    x = ((x ^ (x >> U64(27))) * U64(0x94D049BB133111EB)) & MASK
    return x ^ (x >> U64(31))

def lattice(seed, c0, c1, c2):
    h = mix64(U64(seed) ^ (c0 * U64(0x8DA6B343)) & MASK ^ (c1 * U64(0xD8163841)) & MASK ^ (c2 * U64(0xCB1AB31F)) & MASK)
    return f32(np.float32(h >> U64(40)) * f32(1.0 / (1 << 23)) - f32(1.0))

def lattice_arr(seed, c0, c1, c2):
    # c*: uint64 numpy arrays
    with np.errstate(over='ignore'):
        h = mix64((U64(seed) ^ ((c0 * U64(0x8DA6B343)) & MASK) ^ ((c1 * U64(0xD8163841)) & MASK) ^ ((c2 * U64(0xCB1AB31F)) & MASK)))
    return ((h >> U64(40)).astype(f32) * f32(1.0 / (1 << 23)) - f32(1.0))

def smoothstep(t):
    return (t * t * (f32(3.0) - f32(2.0) * t)).astype(f32)

def value_noise(seed, p0, p1, p2):
    # p*: f32 arrays
    cell0 = np.floor(p0).astype(f32); cell1 = np.floor(p1).astype(f32); cell2 = np.floor(p2).astype(f32)
    fx = smoothstep((p0 - cell0).astype(f32)); fy = smoothstep((p1 - cell1).astype(f32)); fz = smoothstep((p2 - cell2).astype(f32))
    c0 = cell0.astype(np.int64).astype(U64); c1 = cell1.astype(np.int64).astype(U64); c2 = cell2.astype(np.int64).astype(U64)
    acc = np.zeros_like(p0, dtype=f32)
    for corner in range(8):
        o0, o1, o2 = corner & 1, (corner >> 1) & 1, (corner >> 2) & 1
        w = ((fx if o0 else (f32(1.0) - fx)) * (fy if o1 else (f32(1.0) - fy))).astype(f32)
        w = (w * (fz if o2 else (f32(1.0) - fz))).astype(f32)
        l = lattice_arr(seed, c0 + U64(o0), c1 + U64(o1), c2 + U64(o2))
        acc = (acc + (w * l).astype(f32)).astype(f32)
    return acc

def fbm(seed, p0, p1, p2, octaves, gain):
    amp = f32(1.0); freq = f32(1.0)
    acc = np.zeros_like(p0, dtype=f32); norm = f32(0.0)
    for o in range(octaves):
        s = (U64(seed) + U64(o) * U64(0x9E37)) & MASK
        acc = (acc + (amp * value_noise(s, (p0 * freq).astype(f32), (p1 * freq).astype(f32), (p2 * freq).astype(f32))).astype(f32)).astype(f32)
        norm = f32(norm + amp)
        amp = f32(amp * gain)
        freq = f32(freq * 2.0)
    return (acc / max(norm, np.finfo(f32).tiny)).astype(f32)

def cesm_cldhgh(seed, nr, nc, rows, cols):
    i = np.arange(rows, dtype=np.float64); j = np.arange(cols, dtype=np.float64)
    J, I = np.meshgrid(j, i)
    p0 = (J.astype(f32) / f32(nc) * f32(24.0)).astype(f32)
    p1 = (I.astype(f32) / f32(nr) * f32(12.0)).astype(f32)
    p2 = np.zeros_like(p0)
    v = (fbm(U64(seed) ^ U64(0xC1D), p0, p1, p2, 5, f32(0.55)) * f32(1.4) + f32(0.3)).astype(f32)
    return np.clip(v, f32(0.0), f32(1.0)).astype(f32)

def cesm_ts(seed, nr, nc, rows, cols):
    i = np.arange(rows, dtype=np.float64); j = np.arange(cols, dtype=np.float64)
    J, I = np.meshgrid(j, i)
    lat = ((I.astype(f32) / f32(nr) - f32(0.5)) * f32(np.pi)).astype(f32)
    base = (f32(287.0) - f32(55.0) * (np.sin(lat.astype(f32)).astype(f32) ** 2)).astype(f32)
    p0 = (J.astype(f32) / f32(nc) * f32(16.0)).astype(f32)
    p1 = (I.astype(f32) / f32(nr) * f32(8.0)).astype(f32)
    p2 = np.zeros_like(p0)
    return (base + f32(8.0) * fbm(U64(seed) ^ U64(0x75), p0, p1, p2, 4, f32(0.5))).astype(f32)

def prequant(x, hie):
    # round_ties_even(f32(x*hie))
    return np.rint((x.astype(f32) * f32(hie)).astype(f32)).astype(f32)

def dualquant_block(block, pad, hie, radius):
    """block: (bs,bs) f32; pad scalar fill for halo. returns codes(int), outliers mask."""
    bs = block.shape[0]
    dq = prequant(block, hie)
    pq_pad = prequant(np.array([pad], dtype=f32), hie)[0]
    halo = np.full((bs + 1, bs + 1), pq_pad, dtype=f32)
    halo[1:, 1:] = dq
    w = halo[1:, :-1]; n = halo[:-1, 1:]; nw = halo[:-1, :-1]
    pred = ((w + n).astype(f32) - nw).astype(f32)
    delta = (dq - pred).astype(f32)
    incap = np.abs(delta) < f32(radius)
    codes = np.where(incap, (delta + f32(radius)).astype(np.int64), 0)
    return codes, ~incap, dq

def huffman_lengths(freqs, max_bits=15):
    present = [i for i, x in enumerate(freqs) if x > 0]
    n = len(freqs)
    lens = [0] * n
    if len(present) == 0:
        return lens
    if len(present) == 1:
        lens[present[0]] = 1
        return lens
    heap = [(int(freqs[i]), i) for i in present]
    heapq.heapify(heap)
    parent = {}
    nxt = n
    while len(heap) > 1:
        wa, a = heapq.heappop(heap)
        wb, b = heapq.heappop(heap)
        parent[a] = nxt; parent[b] = nxt
        heapq.heappush(heap, (wa + wb, nxt))
        nxt += 1
    root = heap[0][1]
    for i in present:
        d = 0; node = i
        while node != root:
            node = parent[node]; d += 1
        lens[i] = min(d, 255)
    over = any(lens[i] > max_bits for i in present)
    if over:
        for i in present:
            lens[i] = min(lens[i], max_bits)
        def kraft():
            return sum(1 << (max_bits - lens[i]) for i in present)
        budget = 1 << max_bits
        while kraft() > budget:
            best = None
            for i in present:
                if lens[i] < max_bits and (best is None or lens[i] > lens[best]):
                    best = i
            lens[best] += 1
    return lens

def uvarint_len(v):
    n = 1
    while v >= 0x80:
        v >>= 7; n += 1
    return n

def huffman_blob_size(freqs, total_syms):
    lens = huffman_lengths(list(freqs))
    pairs = [(s, l) for s, l in enumerate(lens) if l > 0]
    hdr = uvarint_len(len(freqs)) + uvarint_len(len(pairs))
    prev = 0
    for s, l in pairs:
        hdr += uvarint_len(s - prev) + 1
        prev = s
    payload_bits = sum(freqs[s] * l for s, l in enumerate(lens))
    return hdr + uvarint_len(total_syms) + (payload_bits + 7) // 8

# ---------------------------------------------------------------- check 1
print("== check 1: real_suite_field_compresses_well (ratio > 4.0) ==")
field = cesm_cldhgh(3, 900, 1800, 128, 256)
bs, radius, eb = 16, 512, 1e-3
hie = 0.5 / eb
codes_all = []
n_out = 0
for bi in range(128 // bs):
    for bj in range(256 // bs):
        blk = field[bi*bs:(bi+1)*bs, bj*bs:(bj+1)*bs]
        codes, outmask, dq = dualquant_block(blk, 0.0, hie, radius)
        codes_all.append(codes.ravel())
        n_out += int(outmask.sum())
codes_all = np.concatenate(codes_all)
freqs = np.bincount(codes_all, minlength=2 * radius)
hsize = huffman_blob_size(freqs, codes_all.size)
# conservative (stored, never-expanding) sizes for the other sections
pos_bytes = n_out * 3 + 6  # varint deltas, <= 3 bytes each here, + lossless hdr
val_bytes = n_out * 4 + 6
pad_bytes = 4 + 6
framing = 48 + 1 + 4 * 16  # header + count + per-section framing upper bound
total = hsize + pos_bytes + val_bytes + pad_bytes + framing
raw = field.size * 4
ent = freqs[freqs > 0] / codes_all.size
entropy = float(-(ent * np.log2(ent)).sum())
print(f"  field range [{field.min():.3f},{field.max():.3f}] flat0/1={(np.sum((field==0)|(field==1))/field.size)*100:.1f}%")
print(f"  outliers={n_out} ({100*n_out/codes_all.size:.3f}%)  code entropy={entropy:.3f} bits")
print(f"  huffman={hsize}B  conservative total={total}B  raw={raw}B  ratio={raw/total:.2f}x")
assert raw / total > 4.0, "RATIO CHECK FAILED"
print("  PASS (ratio > 4.0 with conservative sizing)")

# ---------------------------------------------------------------- check 3
flat = float(np.sum((field == 0) | (field == 1)) / field.size)
print(f"== check 3: flat fraction on slab = {flat*100:.2f}% (test needs >2% on full field)")

# ---------------------------------------------------------------- check 2
print("== check 2: avg padding reduces outliers on TS (eb=1e-2) ==")
ts = cesm_ts(3, 900, 1800, 66, 1800)
eb2 = 1e-2; hie2 = 0.5 / eb2
def count_outliers(field, mode):
    rows, cols = field.shape
    nbr, nbc = (rows + bs - 1) // bs, (cols + bs - 1) // bs
    total_out = 0
    gmean = f32(np.float64(field).mean()) if mode == 'avg-global' else None
    for bi in range(nbr):
        for bj in range(nbc):
            r0, c0 = bi * bs, bj * bs
            valid = field[r0:min(r0+bs, rows), c0:min(c0+bs, cols)]
            if mode == 'zero':
                pad = f32(0.0)
            elif mode == 'avg-global':
                pad = gmean
            else:  # avg-block over valid region
                pad = f32(np.float64(valid).mean())
            blk = np.full((bs, bs), pad, dtype=f32)
            blk[:valid.shape[0], :valid.shape[1]] = valid
            _, outmask, _ = dualquant_block(blk, float(pad), hie2, radius)
            total_out += int(outmask.sum())
    return total_out
z = count_outliers(ts, 'zero')
a = count_outliers(ts, 'avg-global')
b = count_outliers(ts, 'avg-block')
print(f"  zero={z}  avg-global={a}  avg-block={b}")
assert a < z, "avg-global must beat zero"
assert b <= a, "avg-block must be <= avg-global"
print("  PASS")


"""Layer-1 Pallas dual-quantization kernel.

One kernel instance processes ``lanes`` blocks per grid step (the lane tile
is the SIMD-width analog of the paper's AVX2/AVX-512 vector registers: 8
lanes ≈ 256-bit, 16 lanes ≈ 512-bit registers over f32).  The grid walks the
superbatch of ``nb`` blocks, so the HBM→VMEM schedule the paper expressed
with cache blocking is expressed here with a BlockSpec.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness is what the Pallas path certifies (see
DESIGN.md §Hardware-Adaptation; TPU-perf is estimated structurally from the
VMEM footprint, not from interpret-mode wallclock).

Inputs (per call):
  blocks f32[nb, bs^d]   raw data gathered into padded blocks
  pads   f32[nb, 1]      per-block padding scalar (data units)
  ebs    f32[1, 3]       [2*eb, 0.5/eb, radius]
Outputs:
  codes  i32[nb, bs^d]   quant codes, 0 == outlier
  outv   f32[nb, bs^d]   pre-quantized value where outlier, else 0
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift_with_pad(x: jax.Array, axis: int, padq: jax.Array) -> jax.Array:
    """Shift x by +1 along ``axis`` (a spatial axis >= 1), filling the
    vacated border hyperplane with the per-block padding scalar ``padq``
    (shape [lanes] broadcast across spatial dims)."""
    border_shape = list(x.shape)
    border_shape[axis] = 1
    pad_col = jnp.broadcast_to(
        padq.reshape((x.shape[0],) + (1,) * (x.ndim - 1)), tuple(border_shape)
    )
    body = jax.lax.slice_in_dim(x, 0, x.shape[axis] - 1, axis=axis)
    return jnp.concatenate([pad_col, body], axis=axis)


def lorenzo_predict(dq: jax.Array, padq: jax.Array) -> jax.Array:
    """Inclusion-exclusion Lorenzo predictor over the spatial axes of
    dq[lanes, bs^d]; borders read the padding scalar."""
    nd = dq.ndim - 1  # spatial dims
    pred = jnp.zeros_like(dq)
    for mask in range(1, 1 << nd):
        shifted = dq
        bits = 0
        for a in range(nd):
            if (mask >> a) & 1:
                shifted = _shift_with_pad(shifted, a + 1, padq)
                bits += 1
        sign = 1.0 if bits % 2 == 1 else -1.0
        pred = pred + sign * shifted
    return pred


def dualquant_math(blocks, pads, ebs):
    """The shared dual-quant arithmetic (Algorithm 2): pre-quant, Lorenzo
    predict on pre-quantized values, post-quant with outlier split.

    Also used verbatim by the L2 jnp production graph so the Pallas kernel
    and the jnp artifact cannot drift."""
    half_inv_eb = ebs[1]
    radius = ebs[2]
    dq = jnp.round(blocks * half_inv_eb)
    padq = jnp.round(pads.reshape(pads.shape[0]) * half_inv_eb)
    pred = lorenzo_predict(dq, padq)
    delta = dq - pred
    in_cap = jnp.abs(delta) < radius
    codes = jnp.where(in_cap, delta + radius, 0.0).astype(jnp.int32)
    outv = jnp.where(in_cap, jnp.float32(0.0), dq)
    return codes, outv


def _dq_kernel(blocks_ref, pads_ref, ebs_ref, codes_ref, outv_ref):
    ebs = ebs_ref[0, :]
    codes, outv = dualquant_math(blocks_ref[...], pads_ref[...], ebs)
    codes_ref[...] = codes
    outv_ref[...] = outv


@functools.partial(jax.jit, static_argnames=("ndim", "bs", "lanes", "nb"))
def dualquant_pallas(blocks, pads, ebs, *, ndim: int, bs: int, lanes: int, nb: int):
    """Pallas dual-quant over a superbatch of nb blocks, lanes blocks per
    grid step."""
    assert nb % lanes == 0, "superbatch must be a multiple of the lane tile"
    spatial = (bs,) * ndim
    grid = (nb // lanes,)
    zeros = (0,) * ndim

    return pl.pallas_call(
        _dq_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((lanes,) + spatial, lambda i: (i,) + zeros),
            pl.BlockSpec((lanes, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((lanes,) + spatial, lambda i: (i,) + zeros),
            pl.BlockSpec((lanes,) + spatial, lambda i: (i,) + zeros),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb,) + spatial, jnp.int32),
            jax.ShapeDtypeStruct((nb,) + spatial, jnp.float32),
        ],
        interpret=True,
    )(blocks, pads, ebs)


def make_ebs(eb: float, radius: int = 512):
    """Pack the runtime scalars the kernels expect: [[2eb, 0.5/eb, radius]]."""
    return jnp.asarray([[2.0 * eb, 0.5 / eb, float(radius)]], dtype=jnp.float32)


def vmem_footprint_bytes(ndim: int, bs: int, lanes: int) -> int:
    """Structural VMEM estimate per grid step (see DESIGN.md §8): input tile
    + 2 output tiles + ~2 temporaries for the shift/predict chain."""
    tile = lanes * bs**ndim * 4
    return tile * 5

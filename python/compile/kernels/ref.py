"""Pure-numpy reference oracle for the dual-quantization kernel.

This is the *independent* correctness reference: explicit Python loops over
block elements, written directly from Algorithm 2 of the paper (vecSZ,
CS.DC'22), with none of the vectorized shift tricks used by the production
graph in ``model.py`` or the Pallas kernel in ``dualquant.py``.  pytest
checks both implementations against this oracle.

Conventions (normative, mirrored by the Rust implementation):

* pre-quantization: ``d_q = round(d / (2*eb))`` computed in float32.
* Lorenzo prediction inside a block uses the *pre-quantized* neighbour
  values; neighbours that fall outside the block read the block's padding
  scalar (itself pre-quantized).
* post-quantization: ``delta = d_q - pred``; if ``|delta| < radius`` the
  quant-code is ``delta + radius`` (so code 0 is reserved for outliers),
  otherwise code 0 and the pre-quantized value is recorded verbatim.
"""

from __future__ import annotations

import numpy as np

DEFAULT_RADIUS = 512


def prequant(data: np.ndarray, eb: float) -> np.ndarray:
    """d° = round(d / (2 eb)), float32.

    np.rint rounds half-to-even, matching jnp.round; exact .5 ties are
    avoided by the test generators (they are measure-zero on real data).
    """
    return np.rint(np.float32(data) * np.float32(0.5 / eb)).astype(np.float32)


def _neighbor(dq_block: np.ndarray, idx: tuple, off: tuple, pad: np.float32):
    """Value of the neighbour at idx-off, or the padding scalar if any
    coordinate leaves the block."""
    coord = tuple(i - o for i, o in zip(idx, off))
    if any(c < 0 for c in coord):
        return pad
    return dq_block[coord]


def _ie_offsets(nd: int):
    """Inclusion-exclusion (offset, sign) pairs for the Lorenzo predictor."""
    out = []
    for mask in range(1, 1 << nd):
        off = tuple((mask >> a) & 1 for a in range(nd))
        sign = np.float32(1.0 if (sum(off) % 2 == 1) else -1.0)
        out.append((off, sign))
    return out


def lorenzo_predict_block(dq_block: np.ndarray, pad: float) -> np.ndarray:
    """Lorenzo prediction for every element of one block (any ndim 1..3).

    1D: p[i]     = W
    2D: p[i,j]   = W + N - NW
    3D: p[i,j,k] = (W + N + U) - (NW + NU + WU) + NWU
    computed by inclusion-exclusion over non-empty subsets of axes.
    """
    pad = np.float32(pad)
    pred = np.zeros_like(dq_block, dtype=np.float32)
    offsets = _ie_offsets(dq_block.ndim)
    for idx in np.ndindex(*dq_block.shape):
        acc = np.float32(0.0)
        for off, sign in offsets:
            acc += sign * _neighbor(dq_block, idx, off, pad)
        pred[idx] = acc
    return pred


def dualquant_block(data_block, pad_value, eb, radius=DEFAULT_RADIUS):
    """Full dual-quant of one block. Returns (codes i32, outlier_vals f32).

    ``codes[i] == 0`` marks an outlier whose pre-quantized value is stored in
    ``outlier_vals[i]`` (0.0 elsewhere).
    """
    dq = prequant(data_block, eb)
    padq = prequant(np.asarray(pad_value, dtype=np.float32), eb)
    pred = lorenzo_predict_block(dq, padq)
    codes = np.zeros(dq.shape, dtype=np.int32)
    outv = np.zeros(dq.shape, dtype=np.float32)
    for idx in np.ndindex(*dq.shape):
        delta = np.float32(dq[idx] - pred[idx])
        if abs(delta) < radius:
            codes[idx] = np.int32(delta) + radius
        else:
            codes[idx] = 0
            outv[idx] = dq[idx]
    return codes, outv


def dualquant_batch(blocks, pads, eb, radius=DEFAULT_RADIUS):
    """Oracle over a batch of blocks: blocks [NB, bs^d], pads [NB]."""
    codes = np.zeros(blocks.shape, dtype=np.int32)
    outv = np.zeros(blocks.shape, dtype=np.float32)
    for b in range(blocks.shape[0]):
        codes[b], outv[b] = dualquant_block(blocks[b], pads[b], eb, radius)
    return codes, outv


def reconstruct_block(codes, outlier_vals, pad_value, eb, radius=DEFAULT_RADIUS):
    """Sequential (cascading) decompression of one block — the RAW-dependent
    reverse path, matching the Rust decompressor.  Returns d̂ = 2·eb·d°."""
    shape = codes.shape
    padq = prequant(np.asarray(pad_value, dtype=np.float32), eb)
    dq = np.zeros(shape, dtype=np.float32)
    offsets = _ie_offsets(codes.ndim)
    for idx in np.ndindex(*shape):
        if codes[idx] == 0:
            dq[idx] = outlier_vals[idx]
            continue
        pred = np.float32(0.0)
        for off, sign in offsets:
            pred += sign * _neighbor(dq, idx, off, padq)
        dq[idx] = pred + np.float32(int(codes[idx]) - radius)
    return dq * np.float32(2.0 * eb)

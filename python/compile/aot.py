"""AOT pipeline: lower the Layer-2 graphs to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids that the xla_extension 0.5.1
shipped with the rust ``xla`` crate rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [--quick]

Emits one ``dq_{ndim}d_b{bs}_l{lanes}_{impl}.hlo.txt`` per artifact point
plus ``manifest.json`` describing every executable's shapes so the Rust
runtime can pick and batch without re-deriving the matrix.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import input_specs, make_fn

RADIUS = 512

# (ndim, block-size) points from the paper's block-size study (§III-D):
# traditional SZ sizes (256 for 1D, 16x16, and 8^3/16^3 near 6^3) plus the
# vector-register multiples the paper concentrates on.
MATRIX = {
    1: [64, 256],
    2: [16, 32],
    3: [8, 16],
}
LANES = [8, 16]  # AVX2-class and AVX-512-class lane tiles

# Superbatch sizes: nb * bs^d ~= 1Mi elements (4 MiB f32) per call, so one
# executable invocation amortizes PJRT dispatch without blowing the cache.
TARGET_ELEMS = 1 << 20
MIN_NB = 64


def superbatch(ndim: int, bs: int) -> int:
    per_block = bs**ndim
    nb = max(MIN_NB, TARGET_ELEMS // per_block)
    # round down to a power of two so every lane tile divides it
    p = 1
    while p * 2 <= nb:
        p *= 2
    return p


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_points(quick: bool = False):
    """Yield (impl, ndim, bs, lanes, nb) for the full matrix.

    The production (jnp) flavour covers the whole matrix; the pallas flavour
    covers one point per ndim (smallest block, 8 lanes) purely as the
    L1-vs-L2 numerics certificate — interpret-mode pallas inside an HLO
    while-loop is not a performance path on CPU.
    """
    for ndim, sizes in MATRIX.items():
        for bs in sizes if not quick else sizes[:1]:
            nb = superbatch(ndim, bs)
            for lanes in LANES if not quick else LANES[:1]:
                yield ("jnp", ndim, bs, lanes, nb)
        bs = sizes[0]
        yield ("pallas", ndim, bs, 8, superbatch(ndim, bs))


def build(out_dir: str, quick: bool = False) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for impl, ndim, bs, lanes, nb in artifact_points(quick):
        name = f"dq_{ndim}d_b{bs}_l{lanes}_{impl}"
        fn = make_fn(impl, ndim, bs, lanes, nb)
        specs = input_specs(ndim, bs, nb)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "file": fname,
                "impl": impl,
                "ndim": ndim,
                "block_size": bs,
                "lanes": lanes,
                "superbatch": nb,
                "radius": RADIUS,
                "inputs": [
                    {"name": "blocks", "dtype": "f32", "shape": [nb] + [bs] * ndim},
                    {"name": "pads", "dtype": "f32", "shape": [nb, 1]},
                    {"name": "ebs", "dtype": "f32", "shape": [1, 3]},
                ],
                "outputs": [
                    {"name": "codes", "dtype": "i32", "shape": [nb] + [bs] * ndim},
                    {"name": "outv", "dtype": "f32", "shape": [nb] + [bs] * ndim},
                ],
            }
        )
        print(f"  lowered {name}: {len(text)} chars")
    manifest = {"version": 1, "radius": RADIUS, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {out_dir}")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="subset matrix (CI smoke)")
    args = ap.parse_args()
    build(args.out_dir, args.quick)


if __name__ == "__main__":
    main()

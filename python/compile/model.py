"""Layer-2 JAX compression graph.

Two artifact flavours per (ndim, block-size, lanes) point:

* ``jnp``    — the production graph: ``dualquant_math`` applied directly to
  the whole superbatch.  XLA fuses the round/shift/select chain into one
  vectorized elementwise region; this is the artifact the Rust hot path
  executes.
* ``pallas`` — the same math routed through the Layer-1 Pallas kernel
  (interpret=True), used to certify that the kernel and the production
  graph lower to identical numerics.

Both flavours share ``dualquant_math`` from the kernel module, so the only
difference is the HBM→VMEM schedule (BlockSpec grid vs whole-array fusion).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.dualquant import dualquant_math, dualquant_pallas


def dualquant_jnp(blocks: jax.Array, pads: jax.Array, ebs: jax.Array):
    """Production dual-quant graph over a superbatch [nb, bs^d]."""
    return dualquant_math(blocks, pads, ebs[0, :])


def make_fn(impl: str, ndim: int, bs: int, lanes: int, nb: int):
    """Return the traced-callable for one artifact point; the returned
    function takes (blocks, pads, ebs) and returns a tuple (codes, outv)."""
    if impl == "jnp":

        def fn(blocks, pads, ebs):
            codes, outv = dualquant_jnp(blocks, pads, ebs)
            return (codes, outv)

        return fn
    if impl == "pallas":

        def fn(blocks, pads, ebs):
            codes, outv = dualquant_pallas(
                blocks, pads, ebs, ndim=ndim, bs=bs, lanes=lanes, nb=nb
            )
            return (codes, outv)

        return fn
    raise ValueError(f"unknown impl {impl!r}")


def input_specs(ndim: int, bs: int, nb: int):
    """ShapeDtypeStructs for (blocks, pads, ebs) of one artifact point."""
    spatial = (bs,) * ndim
    return (
        jax.ShapeDtypeStruct((nb,) + spatial, jnp.float32),
        jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 3), jnp.float32),
    )


def reconstruct_batch(codes, outv, pads, eb: float, radius: int = 512):
    """Vectorized-across-blocks, sequential-within-block reconstruction
    reference (mirrors the Rust decompressor; test-only, never lowered).

    Works element-by-element with lax.fori_loop over the flattened block in
    row-major order, which preserves the cascading RAW dependence."""
    import numpy as np

    from compile.kernels.ref import reconstruct_block

    out = np.zeros(codes.shape, dtype=np.float32)
    for b in range(codes.shape[0]):
        out[b] = reconstruct_block(
            np.asarray(codes[b]), np.asarray(outv[b]), float(pads[b, 0]), eb, radius
        )
    return out

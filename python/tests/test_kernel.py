"""Kernel-vs-oracle correctness: the CORE numerics signal of the repo.

Checks, over shape/eb/value-distribution sweeps (hypothesis):
  * L2 jnp production graph  == numpy oracle (ref.py)
  * L1 pallas kernel         == numpy oracle and == jnp graph (bit-exact)
  * error-bound invariant: reconstruct(dualquant(x)) is within eb
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.dualquant import dualquant_pallas, make_ebs
from compile.model import dualquant_jnp, reconstruct_batch

RNG = np.random.default_rng(0xC0FFEE)


def gen_blocks(nb, shape, scale=1.0, kind="smooth", rng=RNG):
    """Block batch with controllable character: smooth fields predict well,
    rough ones stress the outlier path."""
    full = (nb,) + shape
    if kind == "smooth":
        x = rng.normal(size=full).astype(np.float32)
        for ax in range(1, len(full)):
            for _ in range(3):
                x = (x + np.roll(x, 1, axis=ax)) * 0.5
        x *= scale
    elif kind == "rough":
        x = (rng.normal(size=full) * scale).astype(np.float32)
    elif kind == "const":
        x = np.full(full, scale, dtype=np.float32)
    else:
        raise ValueError(kind)
    return x.astype(np.float32)


def run_jnp(blocks, pads, eb):
    codes, outv = dualquant_jnp(
        jnp.asarray(blocks), jnp.asarray(pads).reshape(-1, 1), make_ebs(eb)
    )
    return np.asarray(codes), np.asarray(outv)


CASES = [
    (1, 8, "smooth", 1.0, 1e-3),
    (1, 64, "smooth", 10.0, 1e-3),
    (2, 8, "smooth", 1.0, 1e-3),
    (2, 16, "rough", 0.5, 1e-2),
    (3, 8, "smooth", 2.0, 1e-3),
    (3, 8, "rough", 1.0, 1e-2),
]


@pytest.mark.parametrize("ndim,bs,kind,scale,eb", CASES)
def test_jnp_matches_oracle(ndim, bs, kind, scale, eb):
    nb = 4
    blocks = gen_blocks(nb, (bs,) * ndim, scale, kind)
    pads = blocks.reshape(nb, -1).mean(axis=1)
    codes, outv = run_jnp(blocks, pads, eb)
    rcodes, routv = ref.dualquant_batch(blocks, pads, eb)
    np.testing.assert_array_equal(codes, rcodes)
    np.testing.assert_array_equal(outv, routv)


@pytest.mark.parametrize(
    "ndim,bs,lanes", [(1, 8, 2), (1, 64, 8), (2, 8, 4), (2, 16, 8), (3, 8, 2)]
)
def test_pallas_matches_oracle_and_jnp(ndim, bs, lanes):
    nb = 2 * lanes
    eb = 1e-3
    blocks = gen_blocks(nb, (bs,) * ndim, 1.0, "smooth")
    pads = np.zeros(nb, dtype=np.float32)
    pcodes, poutv = dualquant_pallas(
        jnp.asarray(blocks),
        jnp.asarray(pads).reshape(-1, 1),
        make_ebs(eb),
        ndim=ndim,
        bs=bs,
        lanes=lanes,
        nb=nb,
    )
    jcodes, joutv = run_jnp(blocks, pads, eb)
    np.testing.assert_array_equal(np.asarray(pcodes), jcodes)
    np.testing.assert_array_equal(np.asarray(poutv), joutv)
    rcodes, routv = ref.dualquant_batch(blocks, pads, eb)
    np.testing.assert_array_equal(np.asarray(pcodes), rcodes)
    np.testing.assert_array_equal(np.asarray(poutv), routv)


@pytest.mark.parametrize("ndim,bs,kind,scale,eb", CASES)
def test_error_bound_roundtrip(ndim, bs, kind, scale, eb):
    nb = 4
    blocks = gen_blocks(nb, (bs,) * ndim, scale, kind)
    pads = blocks.reshape(nb, -1).mean(axis=1)
    codes, outv = run_jnp(blocks, pads, eb)
    rec = reconstruct_batch(codes, outv, pads.reshape(-1, 1), eb)
    # exact-arithmetic bound is eb; the f32 2*eb*d° multiply adds <= 2 ulp
    tol = eb + 2 * np.spacing(np.max(np.abs(blocks)))
    assert np.max(np.abs(rec - blocks)) <= tol


def test_outlier_split_is_exclusive():
    """code==0 <=> outlier value recorded; in-cap codes never carry values."""
    blocks = gen_blocks(4, (16, 16), 100.0, "rough")
    pads = np.zeros(4, dtype=np.float32)
    codes, outv = run_jnp(blocks, pads, 1e-4)
    assert np.all((codes == 0) == (outv != 0.0) | (codes == 0) & (outv == 0.0))
    # in-cap positions carry no outlier payload
    assert np.all(outv[codes != 0] == 0.0)
    # rough data at tiny eb must actually produce outliers (test is live)
    assert (codes == 0).any()


def test_constant_field_all_predictable():
    """A constant block is perfectly predicted everywhere except where the
    padding scalar misses; with avg padding even borders predict."""
    blocks = gen_blocks(2, (16, 16), 7.25, "const")
    pads = np.full(2, 7.25, dtype=np.float32)
    codes, outv = run_jnp(blocks, pads, 1e-3)
    assert np.all(codes != 0)
    # interior deltas are exactly 0 -> code == radius
    assert np.all(codes == 512)


def test_zero_vs_avg_padding_outliers():
    """The paper's §V-I claim in miniature: on an offset (non-zero-centred)
    field, zero padding produces border outliers that avg padding removes."""
    blocks = gen_blocks(4, (16, 16), 1.0, "smooth") + 50.0
    zcodes, _ = run_jnp(blocks, np.zeros(4, np.float32), 1e-2)
    acodes, _ = run_jnp(blocks, blocks.reshape(4, -1).mean(axis=1), 1e-2)
    assert (zcodes == 0).sum() > 0
    assert (acodes == 0).sum() < (zcodes == 0).sum()


@settings(max_examples=25, deadline=None)
@given(
    ndim=st.integers(1, 3),
    bs_pow=st.integers(1, 3),
    eb_exp=st.integers(-4, -1),
    scale_exp=st.integers(-1, 2),
    seed=st.integers(0, 2**31 - 1),
    kind=st.sampled_from(["smooth", "rough"]),
)
def test_property_jnp_equals_oracle(ndim, bs_pow, eb_exp, scale_exp, seed, kind):
    """hypothesis sweep: arbitrary shape/eb/scale/distribution, jnp graph
    must agree with the loop oracle exactly and respect the error bound."""
    bs = 2 ** (bs_pow + 1)  # 4..16
    eb = 10.0**eb_exp
    rng = np.random.default_rng(seed)
    blocks = gen_blocks(2, (bs,) * ndim, 10.0**scale_exp, kind, rng)
    pads = blocks.reshape(2, -1).mean(axis=1)
    codes, outv = run_jnp(blocks, pads, eb)
    rcodes, routv = ref.dualquant_batch(blocks, pads, eb)
    np.testing.assert_array_equal(codes, rcodes)
    np.testing.assert_array_equal(outv, routv)
    rec = reconstruct_batch(codes, outv, pads.reshape(-1, 1), eb)
    tol = eb + 2 * np.spacing(np.max(np.abs(blocks)))
    assert np.max(np.abs(rec - blocks)) <= tol
